//! The timing plane: a lock-light metrics registry with Prometheus text
//! exposition.
//!
//! Counters, gauges, and fixed-bound histograms are plain atomics (no
//! locks on the token hot path); the per-tier/per-tenant label families
//! take a small mutex only at admission time (a few times per request,
//! never per token). [`MetricsRegistry::render`] emits the Prometheus
//! text format (`# HELP`/`# TYPE` + samples, histogram buckets
//! cumulative under `le`) with a fully deterministic family and label
//! order, and [`parse_exposition`] parses it back — the self-checks use
//! the pair to assert that `GET /metrics` is well-formed and that its
//! request/token/MAC counters equal the engine's analytic accounting
//! exactly.
//!
//! This plane carries wall-clock data by design, which is why it is kept
//! strictly apart from the causal plane ([`super::trace`]): nothing here
//! is ever printed by a self-check or written to the wire event stream.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::exec::SpanObserver;
use crate::util::LatencySummary;

/// Metric name prefix (the binary's namespace).
pub const METRICS_NS: &str = "repro";

/// Fixed histogram bounds (seconds) shared by every latency histogram —
/// fine-grained at the low end because the demo models step in tens of
/// microseconds.
pub const LATENCY_BOUNDS_S: [f64; 12] =
    [0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.5, 2.5];

/// Saturating `u128 -> u64` for MAC counters (the exposition format is
/// f64 anyway; every workload this stack prices fits far below 2^64).
pub fn sat_u64(x: u128) -> u64 {
    u64::try_from(x).unwrap_or(u64::MAX)
}

fn fadd(bits: &AtomicU64, v: f64) {
    let mut cur = bits.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match bits.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(now) => cur = now,
        }
    }
}

fn fmax(bits: &AtomicU64, v: f64) {
    let mut cur = bits.load(Ordering::Relaxed);
    while v > f64::from_bits(cur) {
        match bits.compare_exchange_weak(cur, v.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(now) => cur = now,
        }
    }
}

/// Monotonic counter (atomic, relaxed — totals only, no ordering).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-bound histogram: one overflow bucket past the last bound, an
/// exact sum/count, and the exact observed max (bit-packed f64, safe for
/// the non-negative durations this plane records).
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    /// Per-bucket (non-cumulative) counts; `bounds.len() + 1` entries,
    /// the last one the overflow bucket.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl Histogram {
    pub fn new(bounds: &[f64]) -> Histogram {
        assert!(!bounds.is_empty(), "histogram needs at least one bound");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly ascending"
        );
        Histogram {
            bounds: bounds.to_vec(),
            buckets: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0),
            max_bits: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, v: f64) {
        let i = self.bounds.iter().position(|b| v <= *b).unwrap_or(self.bounds.len());
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        fadd(&self.sum_bits, v);
        fmax(&self.max_bits, v);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    pub fn max(&self) -> f64 {
        f64::from_bits(self.max_bits.load(Ordering::Relaxed))
    }

    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Per-bucket (non-cumulative) counts, overflow bucket last.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Bucket-resolution summary, with the exact tracked max patched in
    /// (the bounds only quantize the percentiles).
    pub fn summary(&self) -> LatencySummary {
        let mut s = LatencySummary::from_histogram(&self.bounds, &self.bucket_counts(), self.sum());
        if s.n > 0 {
            s.max = self.max();
        }
        s
    }
}

/// A counter family keyed by one label value (tier, tenant). Mutex-backed
/// — written a few times per *request* at admission, never per token.
#[derive(Debug, Default)]
pub struct LabeledCounter {
    rows: Mutex<BTreeMap<String, u64>>,
}

impl LabeledCounter {
    pub fn add(&self, label: &str, v: u64) {
        let mut rows = self.rows.lock().expect("labeled counter poisoned");
        *rows.entry(label.to_string()).or_insert(0) += v;
    }

    pub fn get(&self, label: &str) -> u64 {
        self.rows.lock().expect("labeled counter poisoned").get(label).copied().unwrap_or(0)
    }

    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        self.rows.lock().expect("labeled counter poisoned").clone()
    }
}

/// The registry: every engine-plane metric, shared as one `Arc` between
/// the engine session (writer) and the daemon's `/metrics` handler
/// (reader). Counter totals are exact mirrors of the `CoreStats`
/// accounting — the observability self-check asserts equality, not
/// approximation.
#[derive(Debug)]
pub struct MetricsRegistry {
    started: Instant,
    // -- counters (engine lifecycle totals) --
    pub requests: Counter,
    pub scored_tokens: Counter,
    pub prompt_tokens: Counter,
    pub generated_tokens: Counter,
    pub executed_macs: Counter,
    pub admitted_macs: Counter,
    pub preemptions: Counter,
    pub deadline_evictions: Counter,
    pub cancelled: Counter,
    pub decode_rounds: Counter,
    pub dispatch_batches: Counter,
    pub mid_run_admissions: Counter,
    /// Candidate tokens drafted by the speculative draft model.
    pub spec_drafted: Counter,
    /// Drafted candidates the verifier accepted (the acceptance ratio is
    /// `spec_accepted / spec_drafted`, derivable from the exposition).
    pub spec_accepted: Counter,
    /// Drafted candidates rolled back after verification.
    pub spec_rejected: Counter,
    // -- gauges (point-in-time occupancy) --
    pub queue_depth: Gauge,
    pub active_lanes: Gauge,
    pub queued_macs: Gauge,
    // -- histograms (timing distributions) --
    pub ttft: Histogram,
    pub inter_token: Histogram,
    pub queue_wait: Histogram,
    pub prefill_phase: Histogram,
    pub decode_phase: Histogram,
    // -- label families (PR-7 scheduling vocabulary) --
    pub tier_admissions: LabeledCounter,
    pub tenant_requests: LabeledCounter,
    pub tenant_declared_macs: LabeledCounter,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry {
            started: Instant::now(),
            requests: Counter::default(),
            scored_tokens: Counter::default(),
            prompt_tokens: Counter::default(),
            generated_tokens: Counter::default(),
            executed_macs: Counter::default(),
            admitted_macs: Counter::default(),
            preemptions: Counter::default(),
            deadline_evictions: Counter::default(),
            cancelled: Counter::default(),
            decode_rounds: Counter::default(),
            dispatch_batches: Counter::default(),
            mid_run_admissions: Counter::default(),
            spec_drafted: Counter::default(),
            spec_accepted: Counter::default(),
            spec_rejected: Counter::default(),
            queue_depth: Gauge::default(),
            active_lanes: Gauge::default(),
            queued_macs: Gauge::default(),
            ttft: Histogram::new(&LATENCY_BOUNDS_S),
            inter_token: Histogram::new(&LATENCY_BOUNDS_S),
            queue_wait: Histogram::new(&LATENCY_BOUNDS_S),
            prefill_phase: Histogram::new(&LATENCY_BOUNDS_S),
            decode_phase: Histogram::new(&LATENCY_BOUNDS_S),
            tier_admissions: LabeledCounter::default(),
            tenant_requests: LabeledCounter::default(),
            tenant_declared_macs: LabeledCounter::default(),
        }
    }

    /// Observed execution rate in MACs/second since the registry was
    /// created — `None` for a truly cold engine (no work executed yet).
    /// The daemon's `Retry-After` drain estimate divides the queued-MAC
    /// backlog by this.
    pub fn macs_rate(&self) -> Option<f64> {
        let macs = self.executed_macs.get();
        let elapsed = self.started.elapsed().as_secs_f64();
        if macs > 0 && elapsed > 0.0 {
            Some(macs as f64 / elapsed)
        } else {
            None
        }
    }

    /// Render the registry as Prometheus text exposition format
    /// (version 0.0.4): fixed family order, sorted label rows, cumulative
    /// `le` buckets with a closing `+Inf`.
    pub fn render(&self) -> String {
        let mut out = String::with_capacity(8192);
        for (name, help, c) in [
            ("requests_total", "Requests retired by the engine session.", &self.requests),
            ("scored_tokens_total", "Prompt positions scored (Score requests).", &self.scored_tokens),
            ("prompt_tokens_total", "Prompt tokens prefilled (Generate requests).", &self.prompt_tokens),
            ("generated_tokens_total", "Tokens generated (Generate requests).", &self.generated_tokens),
            ("executed_macs_total", "MACs executed by retired requests.", &self.executed_macs),
            ("admitted_macs_total", "Declared MACs charged at admission.", &self.admitted_macs),
            ("preemptions_total", "Batch lanes preempted at a token boundary.", &self.preemptions),
            ("deadline_evictions_total", "Requests evicted by deadline expiry.", &self.deadline_evictions),
            ("cancelled_total", "Requests cancelled mid-flight.", &self.cancelled),
            ("decode_rounds_total", "Decode rounds executed.", &self.decode_rounds),
            ("dispatch_batches_total", "Dispatch batches claimed from the queue.", &self.dispatch_batches),
            ("mid_run_admissions_total", "Admissions into a mid-run freed slot.", &self.mid_run_admissions),
            ("spec_drafted_total", "Candidate tokens drafted by the speculative draft model.", &self.spec_drafted),
            ("spec_accepted_total", "Drafted candidates accepted by the verifier (accept ratio = accepted / drafted).", &self.spec_accepted),
            ("spec_rejected_total", "Drafted candidates rolled back after verification.", &self.spec_rejected),
        ] {
            push_counter(&mut out, name, help, c.get());
        }
        for (name, help, g) in [
            ("queue_depth", "Requests waiting in the admission queue.", &self.queue_depth),
            ("active_lanes", "Lanes currently occupied.", &self.active_lanes),
            ("queued_macs", "Declared-MAC backlog of the admission queue.", &self.queued_macs),
        ] {
            push_gauge(&mut out, name, help, g.get());
        }
        push_labeled(
            &mut out,
            "tier_admissions_total",
            "Admissions per scheduling tier.",
            "tier",
            &self.tier_admissions,
        );
        push_labeled(
            &mut out,
            "tenant_requests_total",
            "Admissions per fairness-ledger tenant.",
            "tenant",
            &self.tenant_requests,
        );
        push_labeled(
            &mut out,
            "tenant_declared_macs_total",
            "Declared MACs charged per tenant at admission.",
            "tenant",
            &self.tenant_declared_macs,
        );
        for (name, help, h) in [
            ("ttft_seconds", "Time to first token (queue wait + prefill).", &self.ttft),
            ("inter_token_seconds", "Latency between consecutive tokens.", &self.inter_token),
            ("queue_wait_seconds", "Submission to admission wait.", &self.queue_wait),
        ] {
            push_histogram(&mut out, name, help, &[], h);
        }
        // the two kernel phases share one family, split by the `phase` label
        let name = "phase_seconds";
        push_help_type(&mut out, name, "Wall-clock per engine kernel phase fan-out.", "histogram");
        push_histogram_rows(&mut out, name, &[("phase", "decode")], &self.decode_phase);
        push_histogram_rows(&mut out, name, &[("phase", "prefill")], &self.prefill_phase);
        out
    }
}

/// The exec pool's span hook routes phase timings into the registry's
/// phase histograms — the timing plane's view of kernel fan-outs.
impl SpanObserver for MetricsRegistry {
    fn span(&self, label: &'static str, _items: usize, seconds: f64) {
        match label {
            "prefill" => self.prefill_phase.observe(seconds),
            "decode" => self.decode_phase.observe(seconds),
            _ => {}
        }
    }
}

fn fmt_f64(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn label_block(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let body: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    format!("{{{}}}", body.join(","))
}

fn push_help_type(out: &mut String, name: &str, help: &str, kind: &str) {
    out.push_str(&format!("# HELP {METRICS_NS}_{name} {help}\n"));
    out.push_str(&format!("# TYPE {METRICS_NS}_{name} {kind}\n"));
}

fn push_counter(out: &mut String, name: &str, help: &str, value: u64) {
    push_help_type(out, name, help, "counter");
    out.push_str(&format!("{METRICS_NS}_{name} {value}\n"));
}

fn push_gauge(out: &mut String, name: &str, help: &str, value: u64) {
    push_help_type(out, name, help, "gauge");
    out.push_str(&format!("{METRICS_NS}_{name} {value}\n"));
}

fn push_labeled(out: &mut String, name: &str, help: &str, label: &str, family: &LabeledCounter) {
    push_help_type(out, name, help, "counter");
    for (value, count) in family.snapshot() {
        let block = label_block(&[(label, &value)]);
        out.push_str(&format!("{METRICS_NS}_{name}{block} {count}\n"));
    }
}

fn push_histogram_rows(out: &mut String, name: &str, labels: &[(&str, &str)], h: &Histogram) {
    let counts = h.bucket_counts();
    let mut cum = 0u64;
    for (i, bound) in h.bounds().iter().enumerate() {
        cum += counts[i];
        let mut all = labels.to_vec();
        let le = fmt_f64(*bound);
        all.push(("le", &le));
        out.push_str(&format!("{METRICS_NS}_{name}_bucket{} {cum}\n", label_block(&all)));
    }
    let mut all = labels.to_vec();
    all.push(("le", "+Inf"));
    out.push_str(&format!("{METRICS_NS}_{name}_bucket{} {}\n", label_block(&all), h.count()));
    let block = label_block(labels);
    out.push_str(&format!("{METRICS_NS}_{name}_sum{block} {}\n", fmt_f64(h.sum())));
    out.push_str(&format!("{METRICS_NS}_{name}_count{block} {}\n", h.count()));
}

fn push_histogram(out: &mut String, name: &str, help: &str, labels: &[(&str, &str)], h: &Histogram) {
    push_help_type(out, name, help, "histogram");
    push_histogram_rows(out, name, labels, h);
}

/// Parse Prometheus text exposition into `sample-key -> value`, where the
/// key is the metric name with its verbatim label block (e.g.
/// `repro_ttft_seconds_bucket{le="0.001"}`). Strict enough to be the
/// self-check's "parses as Prometheus text format" assertion: every
/// non-comment line must be `name[{labels}] value` with a finite value.
pub fn parse_exposition(text: &str) -> Result<BTreeMap<String, f64>> {
    let mut out = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (key, value) = line
            .rsplit_once(' ')
            .with_context(|| format!("line {}: no sample value in `{line}`", lineno + 1))?;
        let name_end = key.find('{').unwrap_or(key.len());
        let name = &key[..name_end];
        if name.is_empty()
            || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
        {
            bail!("line {}: bad metric name `{name}`", lineno + 1);
        }
        if name_end < key.len() && !key.ends_with('}') {
            bail!("line {}: unterminated label block in `{key}`", lineno + 1);
        }
        let v: f64 = value
            .parse()
            .with_context(|| format!("line {}: bad sample value `{value}`", lineno + 1))?;
        if !v.is_finite() {
            bail!("line {}: non-finite sample value `{value}`", lineno + 1);
        }
        out.insert(key.to_string(), v);
    }
    Ok(out)
}

/// Pointwise `after - before` over two exposition scrapes (missing keys
/// read as 0) — how the load generator turns two `/metrics` snapshots
/// into the deltas attributable to its run.
pub fn exposition_delta(
    after: &BTreeMap<String, f64>,
    before: &BTreeMap<String, f64>,
) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    for (k, v) in after {
        out.insert(k.clone(), v - before.get(k).copied().unwrap_or(0.0));
    }
    out
}

/// Recover `(bounds, per-bucket counts, sum)` for the named histogram
/// from parsed exposition samples, de-cumulating the `le` buckets.
/// `None` when the histogram is absent. Works on raw scrapes and on
/// [`exposition_delta`] outputs alike (cumulative counts subtract
/// cleanly).
pub fn histogram_from_samples(
    samples: &BTreeMap<String, f64>,
    name: &str,
) -> Option<(Vec<f64>, Vec<u64>, f64)> {
    let prefix = format!("{name}_bucket{{le=\"");
    let mut rows: Vec<(f64, u64)> = Vec::new();
    let mut overflow = None;
    for (key, value) in samples {
        let Some(rest) = key.strip_prefix(&prefix) else { continue };
        let Some(le) = rest.strip_suffix("\"}") else { continue };
        let cum = value.round().max(0.0) as u64;
        if le == "+Inf" {
            overflow = Some(cum);
        } else {
            rows.push((le.parse().ok()?, cum));
        }
    }
    let total = overflow?;
    if rows.is_empty() {
        return None;
    }
    rows.sort_by(|a, b| a.0.total_cmp(&b.0));
    let bounds: Vec<f64> = rows.iter().map(|r| r.0).collect();
    let mut counts: Vec<u64> = Vec::with_capacity(rows.len() + 1);
    let mut prev = 0u64;
    for &(_, cum) in &rows {
        counts.push(cum.saturating_sub(prev));
        prev = cum;
    }
    counts.push(total.saturating_sub(prev));
    let sum = samples.get(&format!("{name}_sum")).copied().unwrap_or(0.0);
    Some((bounds, counts, sum))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_and_histograms_accumulate() {
        let m = MetricsRegistry::new();
        m.requests.inc();
        m.requests.add(2);
        assert_eq!(m.requests.get(), 3);
        m.queue_depth.set(7);
        assert_eq!(m.queue_depth.get(), 7);
        m.ttft.observe(0.0002);
        m.ttft.observe(0.3);
        assert_eq!(m.ttft.count(), 2);
        assert!((m.ttft.sum() - 0.3002).abs() < 1e-12);
        assert_eq!(m.ttft.max(), 0.3);
        let counts = m.ttft.bucket_counts();
        assert_eq!(counts.iter().sum::<u64>(), 2);
        m.tenant_requests.add("acme", 1);
        m.tenant_requests.add("acme", 1);
        assert_eq!(m.tenant_requests.get("acme"), 2);
        assert_eq!(m.tenant_requests.get("other"), 0);
    }

    #[test]
    fn macs_rate_is_none_until_work_ran() {
        let m = MetricsRegistry::new();
        assert!(m.macs_rate().is_none(), "cold engine has no observed rate");
        m.executed_macs.add(1_000_000);
        std::thread::sleep(std::time::Duration::from_millis(1));
        let rate = m.macs_rate().expect("work ran; rate is observable");
        assert!(rate > 0.0);
    }

    #[test]
    fn render_parses_and_roundtrips_the_counters() {
        let m = MetricsRegistry::new();
        m.requests.add(13);
        m.admitted_macs.add(987_654);
        m.tier_admissions.add("interactive", 3);
        m.tier_admissions.add("batch", 10);
        m.tenant_declared_macs.add("flood", 42);
        m.ttft.observe(0.0004);
        m.ttft.observe(9.9); // overflow bucket
        let text = m.render();
        let samples = parse_exposition(&text).unwrap();
        assert_eq!(samples["repro_requests_total"], 13.0);
        assert_eq!(samples["repro_admitted_macs_total"], 987_654.0);
        assert_eq!(samples["repro_tier_admissions_total{tier=\"interactive\"}"], 3.0);
        assert_eq!(samples["repro_tenant_declared_macs_total{tenant=\"flood\"}"], 42.0);
        assert_eq!(samples["repro_ttft_seconds_count"], 2.0);
        assert_eq!(samples["repro_ttft_seconds_bucket{le=\"+Inf\"}"], 2.0);
        assert_eq!(samples["repro_ttft_seconds_bucket{le=\"0.0005\"}"], 1.0);
        // phase family renders with both labels
        assert!(text.contains("repro_phase_seconds_bucket{phase=\"prefill\",le=\"0.0001\"}"));
    }

    #[test]
    fn histogram_recovers_from_exposition_and_deltas() {
        let m = MetricsRegistry::new();
        for v in [0.0002, 0.0002, 0.004, 9.0] {
            m.inter_token.observe(v);
        }
        let samples = parse_exposition(&m.render()).unwrap();
        let (bounds, counts, sum) =
            histogram_from_samples(&samples, "repro_inter_token_seconds").unwrap();
        assert_eq!(bounds, LATENCY_BOUNDS_S.to_vec());
        assert_eq!(counts.iter().sum::<u64>(), 4);
        assert_eq!(*counts.last().unwrap(), 1, "9.0 lands in the overflow bucket");
        assert!((sum - 9.0044).abs() < 1e-9);
        // a delta against a later scrape isolates the new observations
        let before = samples;
        m.inter_token.observe(0.0002);
        let after = parse_exposition(&m.render()).unwrap();
        let delta = exposition_delta(&after, &before);
        let (_, dcounts, _) = histogram_from_samples(&delta, "repro_inter_token_seconds").unwrap();
        assert_eq!(dcounts.iter().sum::<u64>(), 1);
        assert_eq!(dcounts[1], 1, "only the new 0.0002 sample remains in the delta");
    }

    #[test]
    fn parse_exposition_rejects_malformed_lines() {
        assert!(parse_exposition("repro_x_total 1\n# comment\n\nrepro_y 2.5\n").is_ok());
        assert!(parse_exposition("no-value-here\n").is_err());
        assert!(parse_exposition("bad name 1\n").is_err());
        assert!(parse_exposition("repro_x_total nan\n").is_err());
        assert!(parse_exposition("repro_x{le=\"1\" 3\n").is_err());
    }

    #[test]
    fn span_observer_routes_phase_labels() {
        let m = MetricsRegistry::new();
        m.span("prefill", 4, 0.001);
        m.span("decode", 4, 0.002);
        m.span("decode", 4, 0.003);
        m.span("unknown", 1, 1.0);
        assert_eq!(m.prefill_phase.count(), 1);
        assert_eq!(m.decode_phase.count(), 2);
    }
}
