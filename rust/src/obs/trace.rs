//! The causal plane: a wall-clock-free flight recorder of scheduler and
//! lifecycle decisions.
//!
//! Every [`TraceEvent`] is denominated in *rounds* (the engine's
//! scheduling-step counter) and *arrival sequence numbers* — never
//! timestamps — so a recorded transcript is a pure function of (arrival
//! order, declared cost, tier, deadline) and byte-diffs identically
//! across `--threads`. The recorder is strictly observational: it is an
//! optional ring buffer the engine writes into *after* each decision, so
//! enabling it cannot perturb scheduling, event streams, or printed
//! output (the non-perturbation bar the self-checks assert bitwise).
//!
//! [`reconstruct`] replays a transcript back into the aggregate
//! accounting ([`TraceReplay`]) — admitted MACs, preemption count, the
//! per-tenant ledger — which the self-checks and property tests compare
//! against [`crate::engine::CoreStats`] for exact equality: the trace is
//! complete enough to *be* the scheduler's audit log, not a sample of it.

use std::collections::{BTreeMap, VecDeque};

use crate::util::json::Json;

/// Default flight-recorder ring capacity, in events.
pub const DEFAULT_TRACE_CAP: usize = 65_536;

/// One causal-plane record. All fields are deterministic: ids, arrival
/// seqs, rounds, declared/executed MACs, tier names, bucket credit —
/// no wall clock anywhere.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// A request entered the admission queue with its declared price.
    Enqueued {
        id: usize,
        /// Arrival sequence number (the scheduler's FIFO tie-breaker).
        seq: u64,
        tier: &'static str,
        /// Declared cost: prefill + worst-case decode MACs.
        cost_macs: u128,
        /// Deadline on the session clock, as declared (None = unbounded).
        deadline_s: Option<f64>,
        /// Fairness-ledger key (None bills the anonymous `"-"` row).
        tenant: Option<String>,
    },
    /// A request left the queue and took a slot.
    Admitted {
        id: usize,
        /// Scheduling round of the admission.
        round: u64,
        /// Admission order (the `Admitted` event's `seq`).
        seq: usize,
        tier: &'static str,
        /// The tier bucket's remaining credit *after* the charge
        /// (0 for an unlimited bucket, which is never debited).
        bucket_credit: i128,
        /// True for the work-conserving escape hatch: an idle engine
        /// admitted past a dry bucket rather than stalling.
        forced: bool,
    },
    /// Queued work was held back this round: free slots existed but no
    /// queued tier had bucket credit. `id`/`tier` identify the head of
    /// the queue in scheduling-key order.
    Deferred { id: usize, round: u64, tier: &'static str, reason: &'static str },
    /// A batch lane yielded its slot at a token boundary so waiting
    /// interactive work could admit.
    Preempted { victim: usize, beneficiary: usize, round: u64 },
    /// A lane's prefill (or scoring forward) completed, with the MACs it
    /// executed.
    PrefillDone { id: usize, round: u64, macs: u128 },
    /// One decode round advanced `batch` lanes, executing `macs` in
    /// total. Speculative lanes may emit several tokens per round; the
    /// extra work is inside `macs` (the spec events below carry token
    /// counts only, so replay never double-bills).
    DecodeRound { round: u64, batch: usize, macs: u128 },
    /// A speculative lane drafted `k` candidate tokens on the cheap
    /// artifact this round (round/seq-denominated; MACs live in the
    /// enclosing `DecodeRound`).
    SpecDrafted { id: usize, round: u64, k: usize },
    /// The verifier scored a drafted chunk: `accepted` candidates
    /// matched the verifier's greedy choice, `rejected` were rolled
    /// back (`accepted + rejected` == the round's drafted `k`).
    SpecVerified { id: usize, round: u64, accepted: usize, rejected: usize },
    /// A request retired (from a slot or straight from the queue).
    Finished { id: usize, round: u64, reason: &'static str, tokens: usize },
}

fn obj(entries: Vec<(&'static str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

impl TraceEvent {
    /// The event as a JSON object with sorted keys — the deterministic
    /// JSONL line format of `--trace-out` and `GET /admin/trace`. MACs
    /// are emitted as JSON numbers (f64), which is lossless for every
    /// workload this stack prices and deterministic regardless.
    pub fn to_json(&self) -> Json {
        match self {
            TraceEvent::Enqueued { id, seq, tier, cost_macs, deadline_s, tenant } => {
                let mut entries = vec![
                    ("ev", Json::Str("enqueued".to_string())),
                    ("id", Json::Num(*id as f64)),
                    ("seq", Json::Num(*seq as f64)),
                    ("tier", Json::Str(tier.to_string())),
                    ("cost_macs", Json::Num(*cost_macs as f64)),
                ];
                if let Some(d) = deadline_s {
                    entries.push(("deadline_s", Json::Num(*d)));
                }
                if let Some(t) = tenant {
                    entries.push(("tenant", Json::Str(t.clone())));
                }
                obj(entries)
            }
            TraceEvent::Admitted { id, round, seq, tier, bucket_credit, forced } => obj(vec![
                ("ev", Json::Str("admitted".to_string())),
                ("id", Json::Num(*id as f64)),
                ("round", Json::Num(*round as f64)),
                ("seq", Json::Num(*seq as f64)),
                ("tier", Json::Str(tier.to_string())),
                ("bucket_credit", Json::Num(*bucket_credit as f64)),
                ("forced", Json::Bool(*forced)),
            ]),
            TraceEvent::Deferred { id, round, tier, reason } => obj(vec![
                ("ev", Json::Str("deferred".to_string())),
                ("id", Json::Num(*id as f64)),
                ("round", Json::Num(*round as f64)),
                ("tier", Json::Str(tier.to_string())),
                ("reason", Json::Str(reason.to_string())),
            ]),
            TraceEvent::Preempted { victim, beneficiary, round } => obj(vec![
                ("ev", Json::Str("preempted".to_string())),
                ("victim", Json::Num(*victim as f64)),
                ("beneficiary", Json::Num(*beneficiary as f64)),
                ("round", Json::Num(*round as f64)),
            ]),
            TraceEvent::PrefillDone { id, round, macs } => obj(vec![
                ("ev", Json::Str("prefill_done".to_string())),
                ("id", Json::Num(*id as f64)),
                ("round", Json::Num(*round as f64)),
                ("macs", Json::Num(*macs as f64)),
            ]),
            TraceEvent::DecodeRound { round, batch, macs } => obj(vec![
                ("ev", Json::Str("decode_round".to_string())),
                ("round", Json::Num(*round as f64)),
                ("batch", Json::Num(*batch as f64)),
                ("macs", Json::Num(*macs as f64)),
            ]),
            TraceEvent::SpecDrafted { id, round, k } => obj(vec![
                ("ev", Json::Str("spec_drafted".to_string())),
                ("id", Json::Num(*id as f64)),
                ("round", Json::Num(*round as f64)),
                ("k", Json::Num(*k as f64)),
            ]),
            TraceEvent::SpecVerified { id, round, accepted, rejected } => obj(vec![
                ("ev", Json::Str("spec_verified".to_string())),
                ("id", Json::Num(*id as f64)),
                ("round", Json::Num(*round as f64)),
                ("accepted", Json::Num(*accepted as f64)),
                ("rejected", Json::Num(*rejected as f64)),
            ]),
            TraceEvent::Finished { id, round, reason, tokens } => obj(vec![
                ("ev", Json::Str("finished".to_string())),
                ("id", Json::Num(*id as f64)),
                ("round", Json::Num(*round as f64)),
                ("reason", Json::Str(reason.to_string())),
                ("tokens", Json::Num(*tokens as f64)),
            ]),
        }
    }
}

/// Render a transcript as JSONL (one sorted-key JSON object per line,
/// trailing newline) — deterministic bytes for a deterministic event
/// sequence, which is what `scripts/verify.sh` byte-diffs across thread
/// counts.
pub fn render_jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for ev in events {
        out.push_str(&ev.to_json().to_string());
        out.push('\n');
    }
    out
}

/// Bounded ring buffer of causal-plane events. Owned by the engine
/// session (single writer, no locking); when full, the oldest events are
/// evicted and counted in [`FlightRecorder::dropped`].
#[derive(Debug, Clone)]
pub struct FlightRecorder {
    cap: usize,
    events: VecDeque<TraceEvent>,
    dropped: u64,
}

impl FlightRecorder {
    pub fn new(cap: usize) -> FlightRecorder {
        FlightRecorder { cap: cap.max(1), events: VecDeque::new(), dropped: 0 }
    }

    pub fn record(&mut self, ev: TraceEvent) {
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(ev);
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events evicted by the ring bound (0 = the transcript is complete).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Drain every buffered event, oldest first.
    pub fn drain(&mut self) -> Vec<TraceEvent> {
        self.events.drain(..).collect()
    }
}

/// Aggregate accounting replayed from a transcript — the fields the
/// self-checks and property tests compare against
/// [`crate::engine::CoreStats`] for exact equality.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TraceReplay {
    pub enqueued: usize,
    pub admitted: usize,
    /// Requests retired (== `CoreStats::requests` for a drained session).
    pub finished: usize,
    pub preemptions: usize,
    pub deferrals: usize,
    pub decode_rounds: usize,
    /// Candidate tokens drafted by speculative lanes
    /// (== `CoreStats::spec_drafted`).
    pub spec_drafted: usize,
    /// Drafted candidates the verifier accepted
    /// (== `CoreStats::spec_accepted`).
    pub spec_accepted: usize,
    /// Drafted candidates rolled back (== `CoreStats::spec_rejected`).
    pub spec_rejected: usize,
    /// Sum of declared costs over admissions (== `CoreStats::admitted_macs`).
    pub admitted_macs: u128,
    /// Sum of `PrefillDone` + `DecodeRound` MACs (== `CoreStats::macs`
    /// once every admitted lane has retired).
    pub executed_macs: u128,
    /// Per-tenant `(requests, declared_macs)` ledger replayed from the
    /// `Enqueued` costs of admitted ids (== `CoreStats::tenants`).
    pub tenants: BTreeMap<String, (usize, u128)>,
}

/// Replay a transcript into its aggregate accounting. Joins `Admitted`
/// events with the declared cost and tenant carried by the matching
/// `Enqueued` event, so the returned ledger is exactly what admission
/// charged.
pub fn reconstruct(events: &[TraceEvent]) -> TraceReplay {
    let mut replay = TraceReplay::default();
    let mut declared: BTreeMap<usize, (u128, String)> = BTreeMap::new();
    for ev in events {
        match ev {
            TraceEvent::Enqueued { id, cost_macs, tenant, .. } => {
                replay.enqueued += 1;
                let tenant = tenant.clone().unwrap_or_else(|| "-".to_string());
                declared.insert(*id, (*cost_macs, tenant));
            }
            TraceEvent::Admitted { id, .. } => {
                replay.admitted += 1;
                if let Some((cost, tenant)) = declared.get(id) {
                    replay.admitted_macs += cost;
                    let row = replay.tenants.entry(tenant.clone()).or_default();
                    row.0 += 1;
                    row.1 += cost;
                }
            }
            TraceEvent::Deferred { .. } => replay.deferrals += 1,
            TraceEvent::Preempted { .. } => replay.preemptions += 1,
            TraceEvent::PrefillDone { macs, .. } => replay.executed_macs += macs,
            TraceEvent::DecodeRound { macs, .. } => {
                replay.decode_rounds += 1;
                replay.executed_macs += macs;
            }
            TraceEvent::SpecDrafted { k, .. } => replay.spec_drafted += k,
            TraceEvent::SpecVerified { accepted, rejected, .. } => {
                replay.spec_accepted += accepted;
                replay.spec_rejected += rejected;
            }
            TraceEvent::Finished { .. } => replay.finished += 1,
        }
    }
    replay
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_evicts_oldest_and_counts_drops() {
        let mut rec = FlightRecorder::new(2);
        for id in 0..4 {
            rec.record(TraceEvent::Finished { id, round: 1, reason: "eos", tokens: 1 });
        }
        assert_eq!(rec.len(), 2);
        assert_eq!(rec.dropped(), 2);
        let kept = rec.drain();
        assert!(rec.is_empty());
        let ids: Vec<usize> = kept
            .iter()
            .map(|e| match e {
                TraceEvent::Finished { id, .. } => *id,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ids, [2, 3], "oldest events are evicted first");
    }

    #[test]
    fn jsonl_lines_are_sorted_key_objects() {
        let events = vec![
            TraceEvent::Enqueued {
                id: 7,
                seq: 0,
                tier: "batch",
                cost_macs: 1234,
                deadline_s: Some(2.5),
                tenant: Some("acme".to_string()),
            },
            TraceEvent::DecodeRound { round: 3, batch: 2, macs: 99 },
        ];
        let text = render_jsonl(&events);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(
            lines[0],
            r#"{"cost_macs":1234,"deadline_s":2.5,"ev":"enqueued","id":7,"seq":0,"tenant":"acme","tier":"batch"}"#
        );
        assert_eq!(lines[1], r#"{"batch":2,"ev":"decode_round","macs":99,"round":3}"#);
        // every line parses back
        for line in lines {
            Json::parse(line).unwrap();
        }
    }

    #[test]
    fn reconstruct_joins_admissions_with_declared_costs() {
        let events = vec![
            TraceEvent::Enqueued {
                id: 0,
                seq: 0,
                tier: "batch",
                cost_macs: 100,
                deadline_s: None,
                tenant: Some("a".to_string()),
            },
            TraceEvent::Enqueued {
                id: 1,
                seq: 1,
                tier: "interactive",
                cost_macs: 40,
                deadline_s: None,
                tenant: None,
            },
            TraceEvent::Admitted {
                id: 1,
                round: 1,
                seq: 0,
                tier: "interactive",
                bucket_credit: 0,
                forced: false,
            },
            TraceEvent::Deferred { id: 0, round: 1, tier: "batch", reason: "bucket-exhausted" },
            TraceEvent::Admitted {
                id: 0,
                round: 2,
                seq: 1,
                tier: "batch",
                bucket_credit: -60,
                forced: false,
            },
            TraceEvent::PrefillDone { id: 1, round: 1, macs: 30 },
            TraceEvent::SpecDrafted { id: 1, round: 1, k: 3 },
            TraceEvent::SpecVerified { id: 1, round: 1, accepted: 2, rejected: 1 },
            TraceEvent::DecodeRound { round: 1, batch: 1, macs: 10 },
            TraceEvent::Preempted { victim: 0, beneficiary: 1, round: 3 },
            TraceEvent::Finished { id: 0, round: 3, reason: "preempted", tokens: 1 },
            TraceEvent::Finished { id: 1, round: 4, reason: "eos", tokens: 2 },
        ];
        let replay = reconstruct(&events);
        assert_eq!(replay.enqueued, 2);
        assert_eq!(replay.admitted, 2);
        assert_eq!(replay.finished, 2);
        assert_eq!(replay.preemptions, 1);
        assert_eq!(replay.deferrals, 1);
        assert_eq!(replay.decode_rounds, 1);
        assert_eq!(replay.spec_drafted, 3);
        assert_eq!(replay.spec_accepted, 2);
        assert_eq!(replay.spec_rejected, 1);
        assert_eq!(replay.admitted_macs, 140);
        assert_eq!(replay.executed_macs, 40, "spec events carry counts, not MACs");
        assert_eq!(replay.tenants.get("a"), Some(&(1, 100)));
        assert_eq!(replay.tenants.get("-"), Some(&(1, 40)));
    }
}
