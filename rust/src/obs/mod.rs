//! Observability plane: deterministic flight recording + wall-clock metrics.
//!
//! Two strictly separated planes, one invariant:
//!
//! * **Causal plane** ([`trace`]) — a ring-buffer flight recorder of
//!   structured scheduler/lifecycle events. Every field is denominated in
//!   rounds, arrival sequence numbers, and priced MACs — never wall
//!   clock — so a recorded transcript is byte-diffable across
//!   `--threads` counts and machine speeds. Exported as JSONL via
//!   `repro daemon --trace-out` and `GET /admin/trace`.
//! * **Timing plane** ([`metrics`]) — a lock-light registry of counters,
//!   gauges, and fixed-bound histograms (TTFT, inter-token, queue wait,
//!   per-phase kernel time) exposed as Prometheus text on
//!   `GET /metrics`, with per-tier/per-tenant labels from the fairness
//!   ledger. Wall clock lives here and only here.
//!
//! The invariant that makes this a correctness feature rather than
//! logging: attaching either plane never changes scheduling decisions,
//! token output, or printed self-check text (asserted bitwise by
//! `scripts/verify.sh`), and the timing plane's counter totals equal the
//! engine's analytic `CostModel`/`CoreStats` accounting exactly (asserted
//! by the `repro daemon --self-check` observability phase).

pub mod metrics;
pub mod trace;

pub use metrics::{
    exposition_delta, histogram_from_samples, parse_exposition, sat_u64, Counter, Gauge,
    Histogram, LabeledCounter, MetricsRegistry, LATENCY_BOUNDS_S, METRICS_NS,
};
pub use trace::{
    reconstruct, render_jsonl, FlightRecorder, TraceEvent, TraceReplay, DEFAULT_TRACE_CAP,
};
