//! Unified request lifecycle — one streaming inference core behind both
//! front-ends.
//!
//! Before this module the repo had two disjoint batch-in/batch-out
//! request paths: the serve engine's worker queue and the decode
//! scheduler's continuous-batching loop, each reimplementing admission,
//! completion, and stats. They are now thin adapters over one core:
//!
//! - [`InferenceRequest`] — the unified request (`Score` for full-forward
//!   logits, `Generate` for KV-cached generation), with an optional
//!   per-request deadline. [`crate::serve::ServeRequest`] and
//!   [`crate::decode::GenRequest`] convert into it losslessly.
//! - [`EngineCore`] / [`Session`] — the event-driven lifecycle: `submit`
//!   into a **bounded, priced admission queue** (backpressure hands the
//!   request back; caps can be denominated in queued MACs as well as
//!   request count), `step` the deterministic scheduling loop (admission
//!   from the [`Scheduler`] in (deadline, tier, arrival) order under
//!   per-tier MAC budgets, parallel prefill/score, one-token decode
//!   rounds on the [`crate::exec::ExecPool`]), drain the per-request
//!   [`Event`] stream (`Admitted` / `Prefilled{ttft}` / `Token{id, text}`
//!   / `Finished{reason}`), and `cancel` mid-flight. Event order and
//!   payloads are bitwise invariant to `--threads` and slot timing;
//!   TTFT/inter-token stats derive from the event timestamps.
//! - [`Scheduler`] — the admission policy behind `step`: every queued
//!   [`InferenceRequest`] is priced analytically up-front
//!   ([`crate::model::macs::CostModel`]), ordered earliest-deadline-first
//!   (then [`Tier`], then arrival), and metered against per-tier MAC
//!   token buckets. With one tier, no deadlines, and unlimited buckets —
//!   the default — the policy reduces *exactly* to the old FIFO.
//! - [`FinishReason`] — why a request retired: `Eos`, `MaxTokens`,
//!   `Scored`, plus the mid-flight evictions `Cancelled`, `Deadline`, and
//!   `Preempted` (all keep the partial stream and free the slot for the
//!   queue).
//! - [`CoreStats`] — the aggregate superset both adapters project into
//!   [`crate::serve::ServeStats`] / [`crate::decode::DecodeStats`] via the
//!   shared [`crate::util::RequestStats`] core.
//! - [`EngineSnapshot`] — a cheap live view of a running session (queue
//!   depth, slot occupancy, retired totals) for health endpoints and
//!   load-shedding decisions; [`Session::drain_finished`] hands results
//!   out incrementally for long-lived drivers like [`crate::daemon`].
//!
//! A core built with [`EngineCore::with_draft`] additionally runs
//! rank-ladder **speculative decoding** on its generate lanes: a
//! low-budget draft artifact of the same checkpoint proposes `spec_k`
//! tokens per round and the serving model verifies them in one chunked
//! batched forward ([`crate::decode::spec`]). Greedy streams stay bitwise
//! identical to plain decode, executed MACs equal the analytic
//! [`crate::model::macs::spec_report`] accounting, non-greedy sampling
//! falls back to plain decode, and acceptance counts surface in
//! [`CoreStats`] and the obs planes.
//!
//! `repro generate --stream` prints the token events as they are
//! produced, `examples/streaming_generation.rs` drives the session API
//! directly, and `repro generate --stream --self-check` asserts the
//! streamed events reproduce the batch `run()` results exactly.

pub mod core;
pub mod request;
pub mod scheduler;

use crate::model::ModelConfig;
use crate::util::Rng;

pub use self::core::{CoreStats, EngineConfig, EngineCore, EngineSnapshot, Session, TenantUsage};
pub(crate) use self::core::request_rng;
pub use self::request::{
    Event, EventKind, FinishReason, FinishedRequest, InferenceRequest, RequestKind, StreamControl,
    Tier,
};
pub use self::scheduler::Scheduler;

/// The one synthetic-workload generator behind every front-end:
/// `n` streams of `seq` seeded random in-vocab tokens. The serve
/// ([`crate::serve::synth_requests`]) and decode
/// ([`crate::decode::synth_gen_requests`]) helpers, the benches, and the
/// self-checks all wrap this, so identical `(n, seq, seed)` always means
/// identical token streams across the whole repo.
pub fn synth_token_streams(cfg: &ModelConfig, n: usize, seq: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed ^ 0x5E4E);
    (0..n)
        .map(|_| (0..seq.max(1)).map(|_| rng.below(cfg.vocab) as i32).collect())
        .collect()
}

/// Synthetic [`InferenceRequest::generate`] workload over
/// [`synth_token_streams`] (ids are 0-based stream order).
pub fn synth_generate_requests(
    cfg: &ModelConfig,
    n: usize,
    prompt_len: usize,
    seed: u64,
) -> Vec<InferenceRequest> {
    synth_token_streams(cfg, n, prompt_len, seed)
        .into_iter()
        .enumerate()
        .map(|(id, prompt)| InferenceRequest::generate(id, prompt, None))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::demo_config;

    #[test]
    fn synth_streams_are_deterministic_and_in_vocab() {
        let cfg = demo_config();
        let a = synth_token_streams(&cfg, 4, 16, 9);
        let b = synth_token_streams(&cfg, 4, 16, 9);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
        for s in &a {
            assert_eq!(s.len(), 16);
            assert!(s.iter().all(|&t| (t as usize) < cfg.vocab));
        }
        // zero-length requests still carry one token (the old contract)
        assert_eq!(synth_token_streams(&cfg, 1, 0, 9)[0].len(), 1);
    }

    #[test]
    fn synth_generate_requests_wrap_the_streams() {
        let cfg = demo_config();
        let reqs = synth_generate_requests(&cfg, 3, 8, 5);
        let streams = synth_token_streams(&cfg, 3, 8, 5);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i);
            match &r.kind {
                RequestKind::Generate { prompt, max_new } => {
                    assert_eq!(prompt, &streams[i]);
                    assert!(max_new.is_none());
                }
                _ => panic!("wrong kind"),
            }
        }
    }
}
