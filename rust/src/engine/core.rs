//! The shared streaming inference core: one event-driven request
//! lifecycle behind both the serve engine and the decode scheduler.
//!
//! An [`EngineCore`] binds a loaded [`ServeModel`] to an [`EngineConfig`]
//! and opens [`Session`]s. A session is a deterministic, explicitly
//! stepped event loop:
//!
//! - [`Session::submit`] places a request in a **bounded admission queue**
//!   ([`EngineConfig::queue_cap`]); a full queue is backpressure, surfaced
//!   either as a clean `Err` (`submit`) or as the request handed back
//!   ([`Session::try_submit`]) so the caller can drive the loop and retry.
//! - [`Session::step`] runs one scheduling round: expired deadlines are
//!   enforced, over-budget batch lanes are preempted when admissible
//!   interactive work waits, free slots are filled from the **priced
//!   admission queue** ([`super::Scheduler`]: earliest-deadline-first,
//!   tier-ranked, per-tier MAC token buckets — exact FIFO in the default
//!   single-tier/unmetered config; each claim of up to
//!   [`EngineConfig::max_admit`] requests is one *dispatch batch*),
//!   fresh lanes are prefilled/scored in parallel on the [`ExecPool`], and
//!   every active generation advances by exactly one token (round-robin
//!   fairness, the decode scheduler's contract). Every request's cost is
//!   declared up-front ([`crate::model::macs::RequestCost`]) and metered
//!   at admission — scheduling depends only on (arrival order, declared
//!   cost, tier, deadline), never wall clock.
//! - Progress streams out as [`Event`]s — `Admitted` / `Prefilled{ttft}` /
//!   `Token{id, text}` / `Finished{reason}` — drained with
//!   [`Session::next_event`] / [`Session::take_events`]. Event order and
//!   payloads are **bitwise invariant** to the thread count, the slot
//!   count, and admission timing: workers write into their own lanes and
//!   events are emitted serially in admission order after each join.
//!   TTFT and inter-token latency are derived from the event timestamps
//!   themselves, so the reported percentiles *are* the event timeline.
//! - [`Session::cancel`] evicts a request mid-flight (queued or active),
//!   and a per-request deadline ([`InferenceRequest::deadline_s`]) does
//!   the same on expiry — either way the slot is released and the queue
//!   drains into it on the next step, exactly like an EOS eviction.
//!
//! [`EngineCore::run`] is the batch convenience both adapters use: it
//! feeds the queue under backpressure, steps to completion, and returns
//! ordered [`FinishedRequest`]s plus the aggregate [`CoreStats`].

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::data::Tokenizer;
use crate::decode::spec::{spec_round, SpecState};
use crate::decode::{KvCache, KvCachePool, Sampling};
use crate::exec::{ExecConfig, ExecPool, SpanObserver};
use crate::model::macs::{CostModel, RequestCost};
use crate::obs::{sat_u64, FlightRecorder, MetricsRegistry, TraceEvent};
use crate::serve::{ServeModel, ServeScratch};
use crate::util::{LatencySummary, RequestStats, Rng};

use super::request::{
    Event, EventKind, FinishReason, FinishedRequest, InferenceRequest, RequestKind, StreamControl,
    Tier,
};
use super::scheduler::Scheduler;

/// Engine knobs — the union of the serve and decode front-end knobs, with
/// the same defaults as [`crate::decode::DecodeConfig`].
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Concurrent lanes (KV cache slots for generation requests).
    pub slots: usize,
    /// Bounded admission-queue capacity; submission beyond it is
    /// backpressure, not silent buffering.
    pub queue_cap: usize,
    /// Max requests claimed from the queue per dispatch batch
    /// (the serve engine's `max_batch`); 0 = `slots`.
    pub max_admit: usize,
    /// KV capacity per slot, in tokens. Every generation request must
    /// satisfy `prompt + max_new <= capacity` to be admissible.
    pub capacity: usize,
    /// Default generation cap per request.
    pub max_new: usize,
    pub sampling: Sampling,
    /// Base seed; each request derives an independent stream from it.
    pub seed: u64,
    /// Token that terminates a sequence (`None` disables EOS eviction).
    pub eos: Option<i32>,
    /// Worker-pool budget shared by lane-level fan-out and intra-op row
    /// sharding (event order and payloads are invariant to it).
    pub exec: ExecConfig,
    /// Cap on *lane-level* parallelism within one phase (0 = the thread
    /// budget): at most this many lanes forward concurrently, the rest of
    /// the thread budget row-shards inside each forward. The serve
    /// adapter maps its `workers` knob here, so `workers: 1` still means
    /// sequential request processing with full-width matmuls. Results are
    /// invariant to it; only latency anatomy moves.
    pub lane_parallelism: usize,
    /// Cap on the KV cache pool's preallocated footprint; the pool is
    /// built lazily at the first generation admission and an over-budget
    /// pool is a clean `Err` before allocation.
    pub max_cache_bytes: Option<usize>,
    /// MACs credited to the [`Tier::Interactive`] token bucket per
    /// scheduling round; 0 = unlimited (unmetered, the default).
    pub interactive_macs_per_round: u128,
    /// MACs credited to the [`Tier::Batch`] token bucket per scheduling
    /// round; 0 = unlimited. A finite budget throttles batch admission
    /// (deficit carry-over, never rejection) and arms token-boundary
    /// preemption: an over-budget batch lane yields its slot when
    /// admissible interactive work is waiting.
    pub batch_macs_per_round: u128,
    /// MAC-denominated admission-queue bound: a submission whose declared
    /// cost would push the queued backlog past this sheds as
    /// backpressure, exactly like the count bound `queue_cap`;
    /// 0 = unlimited (count bound only, the default).
    pub max_queued_macs: u128,
    /// Tokens drafted per speculative round (0 = speculative decoding
    /// off). Takes effect only when a draft model is bound
    /// ([`EngineCore::with_draft`]) *and* sampling is greedy — non-greedy
    /// sampling deterministically falls back to the plain decode path.
    pub spec_k: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            slots: 4,
            queue_cap: 64,
            max_admit: 0,
            capacity: 192,
            max_new: 32,
            sampling: Sampling::Greedy,
            seed: 0,
            eos: Some(crate::data::EOS),
            exec: ExecConfig::default(),
            lane_parallelism: 0,
            max_cache_bytes: None,
            interactive_macs_per_round: 0,
            batch_macs_per_round: 0,
            max_queued_macs: 0,
            spec_k: 0,
        }
    }
}

impl EngineConfig {
    /// The per-request admissibility rules [`Session::try_submit`]
    /// enforces, callable up-front by the batch adapters so a bad request
    /// fails before any compute is spent on earlier ones.
    pub fn validate(&self, req: &InferenceRequest) -> Result<()> {
        ensure!(req.prompt_len() > 0, "request {}: empty prompt", req.id);
        if let RequestKind::Generate { ref prompt, max_new } = req.kind {
            let max_new = max_new.unwrap_or(self.max_new).max(1);
            ensure!(
                prompt.len() + max_new <= self.capacity,
                "request {}: prompt {} + max_new {max_new} exceeds KV capacity {}",
                req.id,
                prompt.len(),
                self.capacity
            );
        }
        Ok(())
    }

    /// [`EngineConfig::validate`] over a whole batch, plus duplicate-id
    /// rejection — the one up-front check both batch adapters run so a
    /// bad batch fails before any compute is spent on earlier requests.
    pub fn validate_batch(&self, reqs: &[InferenceRequest]) -> Result<()> {
        let mut ids = BTreeSet::new();
        for r in reqs {
            self.validate(r)?;
            ensure!(ids.insert(r.id), "request {}: duplicate id in this batch", r.id);
        }
        Ok(())
    }
}

/// The per-request RNG stream: independent of scheduling, stable across
/// slot counts — shared with the recompute baseline so both paths draw
/// identical samples.
pub(crate) fn request_rng(seed: u64, id: usize) -> Rng {
    Rng::new(seed ^ (id as u64).wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(0xD0DE))
}

/// Aggregate accounting of one session — the superset both adapters
/// project their stats from.
#[derive(Debug, Clone, Default)]
pub struct CoreStats {
    pub requests: usize,
    /// Dispatch batches claimed from the queue.
    pub batches: usize,
    /// Prompt positions scored (Score requests).
    pub scored_tokens: usize,
    /// Prompt tokens consumed by generation requests (prefill).
    pub prompt_tokens: usize,
    /// Tokens generated (Generate requests).
    pub generated_tokens: usize,
    pub macs: u128,
    /// Analytic cache-less recompute MACs of the generation streams (plus
    /// the scored MACs, which are their own baseline).
    pub recompute_macs: u128,
    pub wall_s: f64,
    /// Per-request completion latency.
    pub latency: LatencySummary,
    /// Time to first token per generation request, derived from the
    /// `Prefilled` event timestamps.
    pub ttft: LatencySummary,
    /// Latency between consecutive `Token` events of a request.
    pub inter_token: LatencySummary,
    pub peak_active: usize,
    /// Admissions into a slot another request freed mid-run.
    pub mid_run_admissions: usize,
    /// Decode rounds executed (each advances every active sequence by one
    /// token — the fairness unit).
    pub decode_rounds: usize,
    /// Requests evicted by [`Session::cancel`].
    pub cancelled: usize,
    /// Requests evicted by deadline expiry.
    pub deadline_evictions: usize,
    /// Batch lanes preempted at a token boundary for waiting interactive
    /// work ([`FinishReason::Preempted`]).
    pub preemptions: usize,
    /// Declared-cost meter: the sum of [`RequestCost::total_macs`] over
    /// every admitted request — what admission *charged*, asserted by the
    /// self-checks to equal the analytic
    /// [`crate::model::macs::decode_report`] sums.
    pub admitted_macs: u128,
    /// Per-tenant fairness ledger, recorded at admission with the
    /// declared cost; requests without a tenant bill the `"-"` row.
    pub tenants: BTreeMap<String, TenantUsage>,
    /// Candidate tokens drafted by speculative lanes (0 without a draft
    /// model bound).
    pub spec_drafted: usize,
    /// Drafted candidates the verifier accepted — the acceptance rate is
    /// `spec_accepted / spec_drafted`.
    pub spec_accepted: usize,
    /// Drafted candidates rolled back after verification (their MACs
    /// stay in [`CoreStats::macs`]: speculation waste is billed).
    pub spec_rejected: usize,
}

/// One row of the per-tenant fairness ledger in [`CoreStats::tenants`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TenantUsage {
    /// Requests admitted for this tenant.
    pub requests: usize,
    /// Declared MACs charged at those admissions.
    pub declared_macs: u128,
}

impl CoreStats {
    /// This run reduced to the shared [`RequestStats`] core, counting
    /// `tokens` delivered as scored positions plus generated tokens.
    pub fn request_stats(&self) -> RequestStats {
        RequestStats {
            requests: self.requests,
            tokens: self.scored_tokens + self.generated_tokens,
            macs: self.macs,
            wall_s: self.wall_s,
            latency: self.latency,
        }
    }
}

/// A cheap point-in-time view of a live [`Session`] — what a transport
/// front-end needs for health endpoints and load-shedding decisions
/// without touching the event stream: bounded-queue occupancy, slot
/// occupancy, and the cumulative totals of everything retired so far.
/// Produced by [`Session::snapshot`] from plain counter reads (no
/// allocation, no locking, no interaction with event delivery).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EngineSnapshot {
    /// Requests waiting in the bounded admission queue.
    pub queue_depth: usize,
    /// The queue bound ([`EngineConfig::queue_cap`], min-clamped to 1) —
    /// `queue_depth == queue_cap` is the 429 shedding condition.
    pub queue_cap: usize,
    /// Lanes currently occupied.
    pub active: usize,
    /// Total lanes ([`EngineConfig::slots`], min-clamped to 1).
    pub slots: usize,
    /// `slots - active`.
    pub free_slots: usize,
    /// Requests admitted into a slot so far.
    pub admitted: usize,
    /// Requests retired so far (including drained ones).
    pub finished: usize,
    /// Prompt positions scored so far (Score requests).
    pub scored_tokens: usize,
    /// Tokens generated so far (Generate requests).
    pub generated_tokens: usize,
    /// MACs executed by retired requests.
    pub macs: u128,
    pub cancelled: usize,
    pub deadline_evictions: usize,
    pub mid_run_admissions: usize,
    pub decode_rounds: usize,
    /// Declared-MAC backlog of the admission queue (prefill + worst-case
    /// decode of every waiting request) — what the daemon's `Retry-After`
    /// drain estimate and MAC-denominated shedding read.
    pub queued_macs: u128,
}

/// Running totals over every retired request, recorded at retire time so
/// they survive [`Session::drain_finished`] handing the per-request
/// results out incrementally. [`Session::finish`] projects [`CoreStats`]
/// from this tally; for drain-free sessions (the batch adapters) the
/// numbers are identical to folding over the finished list.
#[derive(Debug, Clone, Copy, Default)]
struct FinishTally {
    requests: usize,
    scored_tokens: usize,
    prompt_tokens: usize,
    generated_tokens: usize,
    macs: u128,
    recompute_macs: u128,
}

impl FinishTally {
    fn record(&mut self, f: &FinishedRequest) {
        self.requests += 1;
        self.macs += f.macs;
        self.recompute_macs += f.recompute_macs;
        if f.is_generate {
            // a request cancelled straight from the queue never
            // prefilled, so its prompt was not consumed
            if f.admitted.is_some() {
                self.prompt_tokens += f.prompt_len;
            }
            self.generated_tokens += f.tokens.len();
        } else if f.reason == FinishReason::Scored {
            self.scored_tokens += f.prompt_len;
        }
    }
}

/// A request occupying a lane (slot) for the duration of its life.
struct Lane {
    id: usize,
    admitted: usize,
    deadline_s: Option<f64>,
    /// Scheduling tier, for the preemption victim scan.
    tier: Tier,
    macs: u128,
    ttft_s: f64,
    /// Timestamp of this lane's previous token (inter-token base).
    last_s: f64,
    /// Timestamp taken inside the worker for the current phase's result —
    /// the value stamped on this phase's events.
    step_t_s: f64,
    done: Option<FinishReason>,
    kind: LaneKind,
}

enum LaneKind {
    Score {
        tokens: Vec<i32>,
        logits: Vec<f32>,
    },
    Generate {
        prompt: Vec<i32>,
        max_new: usize,
        tokens: Vec<i32>,
        cache: KvCache,
        rng: Rng,
        recompute_macs: u128,
        /// Per-lane scratch arena: steady-state decode rounds run the
        /// `*_scratch` forwards with zero hot-path allocation. Lanes are
        /// forwarded by independent workers, so each needs its own.
        scratch: ServeScratch,
        /// Speculative lane state (draft cache + draft scratch + chunk
        /// buffer), present only when the session runs speculatively —
        /// per-lane, preallocated at admission like `scratch`.
        spec: Option<Box<SpecState>>,
    },
}

/// The streaming inference core over one loaded model (plus, in
/// speculative mode, a cheap draft model of the same checkpoint).
#[derive(Clone, Copy)]
pub struct EngineCore<'m> {
    model: &'m ServeModel,
    /// Draft model for speculative decoding (same checkpoint family at a
    /// lower budget); `None` runs the plain decode path.
    draft: Option<&'m ServeModel>,
    config: EngineConfig,
}

impl<'m> EngineCore<'m> {
    pub fn new(model: &'m ServeModel, config: EngineConfig) -> EngineCore<'m> {
        EngineCore { model, draft: None, config }
    }

    /// Bind a draft model for speculative decoding. The pair must share
    /// one [`crate::model::ModelConfig`] (two budgets of the same
    /// checkpoint — the artifact-level contract is
    /// [`crate::compress::CompressedModel::check_spec_draft`]).
    pub fn with_draft(
        model: &'m ServeModel,
        draft: &'m ServeModel,
        config: EngineConfig,
    ) -> Result<EngineCore<'m>> {
        ensure!(
            draft.config() == model.config(),
            "draft and verifier models are from different checkpoint families \
             (configs differ); speculative decoding pairs two budgets of one checkpoint"
        );
        ensure!(
            config.spec_k > 0,
            "a draft model is bound but spec_k is 0: set EngineConfig::spec_k >= 1"
        );
        Ok(EngineCore { model, draft: Some(draft), config })
    }

    pub fn model(&self) -> &'m ServeModel {
        self.model
    }

    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// True when generation lanes will run speculatively: a draft model
    /// is bound, `spec_k >= 1`, and sampling is greedy (non-greedy
    /// sampling falls back to the plain decode path deterministically).
    pub fn speculative(&self) -> bool {
        self.draft.is_some()
            && self.config.spec_k > 0
            && matches!(self.config.sampling, Sampling::Greedy)
    }

    /// Open a fresh session (its own clock, queue, slots, and events).
    pub fn session(&self) -> Session<'m> {
        Session {
            core: *self,
            t0: Instant::now(),
            tokenizer: Tokenizer::new(),
            pool: None,
            draft_pool: None,
            // the pricer: the model's measured single-token MAC unit
            // closed over its config — the same unit the serve path
            // asserts equals the analytic accounting
            cost_model: CostModel::new(self.model.config(), self.model.macs_for(1)),
            pending: Scheduler::new(
                self.config.interactive_macs_per_round,
                self.config.batch_macs_per_round,
            ),
            collect_events: true,
            seen_ids: BTreeSet::new(),
            active: Vec::new(),
            finished: Vec::new(),
            tally: FinishTally::default(),
            lats: Vec::new(),
            events: VecDeque::new(),
            ttfts: Vec::new(),
            itls: Vec::new(),
            admitted_count: 0,
            slot_retirements: 0,
            batches: 0,
            mid_run: 0,
            peak_active: 0,
            rounds: 0,
            cancelled: 0,
            deadline_evictions: 0,
            preemptions: 0,
            admitted_macs: 0,
            tenant_ledger: BTreeMap::new(),
            recorder: None,
            metrics: None,
            submit_t: BTreeMap::new(),
            sched_rounds: 0,
            spec_drafted: 0,
            spec_accepted: 0,
            spec_rejected: 0,
        }
    }

    /// Batch convenience: feed every request through a session under
    /// queue backpressure and step it to completion, discarding the event
    /// stream. Results are returned in request id order.
    pub fn run(
        &self,
        requests: Vec<InferenceRequest>,
    ) -> Result<(Vec<FinishedRequest>, CoreStats)> {
        self.drive_queue(requests, None)
    }

    /// The callback face of the core: drive the whole workload to
    /// completion under queue backpressure, invoking `on_event` for every
    /// event in deterministic order (the same order a hand-driven
    /// [`Session`] would drain). Returning [`StreamControl::Cancel`]
    /// evicts that event's request at the next token boundary.
    pub fn run_streaming<F>(
        &self,
        requests: Vec<InferenceRequest>,
        mut on_event: F,
    ) -> Result<(Vec<FinishedRequest>, CoreStats)>
    where
        F: FnMut(&Event) -> StreamControl,
    {
        self.drive_queue(requests, Some(&mut on_event))
    }

    /// The shared driver behind [`EngineCore::run`] and
    /// [`EngineCore::run_streaming`]. With no consumer, event
    /// construction is skipped entirely (no per-token allocation on the
    /// batch hot path); the timestamps feeding TTFT/inter-token stats are
    /// taken identically either way.
    fn drive_queue(
        &self,
        requests: Vec<InferenceRequest>,
        mut on_event: Option<&mut dyn FnMut(&Event) -> StreamControl>,
    ) -> Result<(Vec<FinishedRequest>, CoreStats)> {
        let mut queue: VecDeque<InferenceRequest> = requests.into();
        let mut session = self.session();
        session.collect_events = on_event.is_some();
        loop {
            while let Some(req) = queue.pop_front() {
                if let Some(back) = session.try_submit(req)? {
                    queue.push_front(back); // bounded queue: retry after a step
                    break;
                }
            }
            let worked = session.step()?;
            if let Some(cb) = on_event.as_mut() {
                let mut cancels: Vec<usize> = Vec::new();
                for ev in session.take_events() {
                    if cb(&ev) == StreamControl::Cancel {
                        cancels.push(ev.id);
                    }
                }
                for id in cancels {
                    session.cancel(id);
                }
            }
            if !worked && queue.is_empty() {
                break;
            }
        }
        Ok(session.finish())
    }
}

/// One live event-driven run: submit / cancel / step / drain events.
pub struct Session<'m> {
    core: EngineCore<'m>,
    t0: Instant,
    tokenizer: Tokenizer,
    /// Lazily built at the first generation admission (scoring-only
    /// sessions never allocate KV).
    pool: Option<KvCachePool>,
    /// The draft model's cache pool, built alongside `pool` in
    /// speculative mode — both families are billed against
    /// [`EngineConfig::max_cache_bytes`] before either allocates.
    draft_pool: Option<KvCachePool>,
    /// The request pricer (per-token MAC unit of this session's model).
    cost_model: CostModel,
    /// The priced admission queue: EDF + tier ordering, per-tier MAC
    /// buckets — exact FIFO under the default config.
    pending: Scheduler,
    /// False on the batch path, where no consumer drains events: skips
    /// event construction (incl. per-token text decoding) entirely while
    /// keeping the TTFT/inter-token timestamps identical.
    collect_events: bool,
    /// Every id ever accepted, for O(1) duplicate rejection.
    seen_ids: BTreeSet<usize>,
    active: Vec<Lane>,
    /// Retired requests not yet handed out ([`Session::drain_finished`]
    /// empties this; [`Session::finish`] returns the remainder).
    finished: Vec<FinishedRequest>,
    /// Totals over *every* retired request, drained or not.
    tally: FinishTally,
    /// Per-request completion-latency samples, recorded at retire time.
    lats: Vec<f64>,
    events: VecDeque<Event>,
    ttfts: Vec<f64>,
    itls: Vec<f64>,
    admitted_count: usize,
    /// Requests retired *from a slot* (the mid-run admission trigger).
    slot_retirements: usize,
    batches: usize,
    mid_run: usize,
    peak_active: usize,
    rounds: usize,
    cancelled: usize,
    deadline_evictions: usize,
    preemptions: usize,
    /// Sum of declared costs over every admission (the meter).
    admitted_macs: u128,
    /// Per-tenant admissions + declared MACs.
    tenant_ledger: BTreeMap<String, TenantUsage>,
    /// Causal-plane flight recorder ([`Session::enable_tracing`]) —
    /// records deterministic scheduler/lifecycle events; never consulted
    /// by any scheduling decision.
    recorder: Option<FlightRecorder>,
    /// Timing-plane sink ([`Session::attach_metrics`]) — counters mirror
    /// the tally exactly; histograms carry wall clock. Never read back.
    metrics: Option<Arc<MetricsRegistry>>,
    /// Submission timestamps for the queue-wait histogram; only populated
    /// while a metrics registry is attached.
    submit_t: BTreeMap<usize, f64>,
    /// Scheduling rounds started — the causal plane's round denomination
    /// (counts every [`Session::step`] with work, unlike `rounds` which
    /// counts decode rounds only).
    sched_rounds: u64,
    /// Speculative totals (candidates drafted / accepted / rejected),
    /// mirrored into [`CoreStats`], the metrics registry, and the trace.
    spec_drafted: usize,
    spec_accepted: usize,
    spec_rejected: usize,
}

impl<'m> Session<'m> {
    fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Free admission-queue capacity before backpressure kicks in.
    pub fn queue_free(&self) -> usize {
        self.core.config.queue_cap.max(1).saturating_sub(self.pending.len())
    }

    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || !self.active.is_empty()
    }

    /// Seconds since this session opened — the clock every event
    /// timestamp and [`InferenceRequest::deadline_s`] is measured
    /// against. A transport front-end converts a client-relative
    /// deadline to this clock with `elapsed_s() + relative`.
    pub fn elapsed_s(&self) -> f64 {
        self.now()
    }

    /// Point-in-time view of the session: queue/slot occupancy plus the
    /// cumulative totals of everything retired so far. Plain counter
    /// reads — cheap enough for a health endpoint to call per request.
    pub fn snapshot(&self) -> EngineSnapshot {
        let slots = self.core.config.slots.max(1);
        EngineSnapshot {
            queue_depth: self.pending.len(),
            queue_cap: self.core.config.queue_cap.max(1),
            active: self.active.len(),
            slots,
            free_slots: slots.saturating_sub(self.active.len()),
            admitted: self.admitted_count,
            finished: self.tally.requests,
            scored_tokens: self.tally.scored_tokens,
            generated_tokens: self.tally.generated_tokens,
            macs: self.tally.macs,
            cancelled: self.cancelled,
            deadline_evictions: self.deadline_evictions,
            mid_run_admissions: self.mid_run,
            decode_rounds: self.rounds,
            queued_macs: self.pending.queued_macs(),
        }
    }

    /// Hand out every request retired since the last drain, in
    /// retirement order. Long-lived drivers (the HTTP daemon) consume
    /// results as they complete instead of holding them until
    /// [`Session::finish`]; the aggregate totals keep accumulating
    /// either way, so `finish()` reports the whole session regardless
    /// of how many results were drained early.
    pub fn drain_finished(&mut self) -> Vec<FinishedRequest> {
        std::mem::take(&mut self.finished)
    }

    /// Arm the causal-plane flight recorder: from now on every
    /// scheduler/lifecycle decision lands in a ring buffer of `capacity`
    /// [`TraceEvent`]s (oldest evicted first). Purely observational — the
    /// recorded run is bitwise identical to an unrecorded one.
    pub fn enable_tracing(&mut self, capacity: usize) {
        self.recorder = Some(FlightRecorder::new(capacity));
    }

    /// Drain the flight recorder's buffered events (empty when tracing
    /// was never enabled). Recording continues afterwards.
    pub fn take_trace(&mut self) -> Vec<TraceEvent> {
        self.recorder.as_mut().map(|r| r.drain()).unwrap_or_default()
    }

    /// Attach the timing-plane metrics registry: lifecycle counters and
    /// latency histograms stream into it from now on. The registry is
    /// write-only for the session — nothing in it feeds back into
    /// scheduling, so output is identical with or without one attached.
    pub fn attach_metrics(&mut self, metrics: Arc<MetricsRegistry>) {
        self.metrics = Some(metrics);
    }

    /// Record a causal-plane event (no-op unless tracing is enabled).
    fn trace(&mut self, ev: TraceEvent) {
        if let Some(rec) = self.recorder.as_mut() {
            rec.record(ev);
        }
    }

    /// Submit, treating a full queue as an error that drops the request.
    /// Prefer [`Session::try_submit`] when driving the loop yourself — it
    /// hands a refused request back so it can be resubmitted after a
    /// `step()` drains the queue.
    pub fn submit(&mut self, req: InferenceRequest) -> Result<()> {
        if let Some(req) = self.try_submit(req)? {
            bail!(
                "admission queue full ({} pending, cap {}): request {} refused and dropped — \
                 use try_submit() to get a refused request handed back for retry",
                self.pending.len(),
                self.core.config.queue_cap.max(1),
                req.id
            );
        }
        Ok(())
    }

    /// Validate, price, and enqueue a request. `Ok(Some(request))` hands
    /// the request back when the bounded queue is full — by count
    /// ([`EngineConfig::queue_cap`]) or by declared MACs
    /// ([`EngineConfig::max_queued_macs`]) — as backpressure (step the
    /// session and retry); `Err` means the request itself is invalid.
    pub fn try_submit(&mut self, req: InferenceRequest) -> Result<Option<InferenceRequest>> {
        self.core.config.validate(&req)?;
        ensure!(
            !self.seen_ids.contains(&req.id),
            "request {}: duplicate id in this session",
            req.id
        );
        if self.pending.len() >= self.core.config.queue_cap.max(1) {
            return Ok(Some(req)); // backpressure (count bound)
        }
        let cost = self.cost_model.price(&req, self.core.config.max_new);
        let mac_cap = self.core.config.max_queued_macs;
        if mac_cap > 0 && self.pending.queued_macs() + cost.total_macs() > mac_cap {
            return Ok(Some(req)); // backpressure (declared-MAC bound)
        }
        self.seen_ids.insert(req.id);
        if self.metrics.is_some() {
            self.submit_t.insert(req.id, self.now());
        }
        let traced = self
            .recorder
            .is_some()
            .then(|| (req.id, req.tier.name(), req.deadline_s, req.tenant.clone()));
        let seq = self.pending.push(req, cost);
        if let Some((id, tier, deadline_s, tenant)) = traced {
            self.trace(TraceEvent::Enqueued {
                id,
                seq,
                tier,
                cost_macs: cost.total_macs(),
                deadline_s,
                tenant,
            });
        }
        Ok(None)
    }

    /// Cancel a request mid-flight. A queued request is retired without
    /// ever taking a slot; an active one is evicted immediately (tokens
    /// produced so far are kept) and its slot freed for the queue.
    /// Returns false when the id is unknown or already finished.
    pub fn cancel(&mut self, id: usize) -> bool {
        if let Some(req) = self.pending.remove(id) {
            self.retire_unadmitted(req, FinishReason::Cancelled);
            return true;
        }
        let mut hit = false;
        for lane in &mut self.active {
            if lane.id == id && lane.done.is_none() {
                lane.done = Some(FinishReason::Cancelled);
                hit = true;
            }
        }
        if hit {
            self.evict_done();
        }
        hit
    }

    /// Pop the oldest undelivered event.
    pub fn next_event(&mut self) -> Option<Event> {
        self.events.pop_front()
    }

    /// Drain every undelivered event, oldest first.
    pub fn take_events(&mut self) -> Vec<Event> {
        self.events.drain(..).collect()
    }

    /// One scheduling round: deadlines → admission → prefill/score →
    /// one decode round. Returns `Ok(false)` when the session is idle
    /// (nothing pending, nothing active).
    pub fn step(&mut self) -> Result<bool> {
        if !self.has_work() {
            return Ok(false);
        }
        self.sched_rounds += 1;
        let round = self.sched_rounds;
        self.enforce_deadlines();
        // refill the per-tier MAC buckets, then let over-budget batch
        // lanes yield their slots to admissible interactive work
        self.pending.begin_round();
        self.preempt_for_interactive();

        // ---- admission: drain the scheduler into free slots in its
        // (deadline, tier, arrival) order, one dispatch batch
        // (<= max_admit requests) per claim; a tier out of bucket credit
        // holds its requests for a later round ----
        let slots = self.core.config.slots.max(1);
        let max_admit = match self.core.config.max_admit {
            0 => slots,
            n => n,
        };
        let mut fresh: Vec<Lane> = Vec::new();
        loop {
            let free = slots - (self.active.len() + fresh.len());
            let claim = free.min(max_admit).min(self.pending.len());
            if claim == 0 {
                break;
            }
            let mut took = 0;
            for _ in 0..claim {
                let Some((req, cost)) = self.pending.pop_admissible() else {
                    break; // queued work exists but no tier has credit
                };
                let (id, tier) = (req.id, req.tier);
                let lane = self.admit(req, cost)?;
                self.trace(TraceEvent::Admitted {
                    id,
                    round,
                    seq: lane.admitted,
                    tier: tier.name(),
                    bucket_credit: self.pending.tier_credit(tier),
                    forced: false,
                });
                fresh.push(lane);
                took += 1;
            }
            if took == 0 {
                // the front-of-queue request is what the dry bucket is
                // holding back this round
                if let Some((id, tier)) = self.pending.peek_front() {
                    self.trace(TraceEvent::Deferred {
                        id,
                        round,
                        tier: tier.name(),
                        reason: "bucket-exhausted",
                    });
                }
                break;
            }
            self.batches += 1;
            if let Some(m) = &self.metrics {
                m.dispatch_batches.inc();
            }
        }
        // work-conserving guarantee: an idle engine never waits on a dry
        // bucket — with every slot free and no tier in credit, the best
        // queued request is admitted anyway (still charged), so metering
        // can delay work but never deadlock it
        if fresh.is_empty() && self.active.is_empty() {
            if let Some((req, cost)) = self.pending.pop_front_forced() {
                let (id, tier) = (req.id, req.tier);
                let lane = self.admit(req, cost)?;
                self.trace(TraceEvent::Admitted {
                    id,
                    round,
                    seq: lane.admitted,
                    tier: tier.name(),
                    bucket_credit: self.pending.tier_credit(tier),
                    forced: true,
                });
                fresh.push(lane);
                self.batches += 1;
                if let Some(m) = &self.metrics {
                    m.dispatch_batches.inc();
                }
            }
        }

        // ---- prefill / score phase: fresh lanes fan out over the pool;
        // leftover thread budget row-shards the matmuls inside each ----
        if !fresh.is_empty() {
            self.forward_fresh(&mut fresh)?;
            for mut lane in fresh {
                self.trace(TraceEvent::PrefillDone { id: lane.id, round, macs: lane.macs });
                match &lane.kind {
                    LaneKind::Score { .. } => {
                        lane.ttft_s = lane.step_t_s;
                        lane.last_s = lane.step_t_s;
                    }
                    LaneKind::Generate { prompt, tokens, .. } => {
                        let t = lane.step_t_s;
                        if self.collect_events {
                            self.events.push_back(Event {
                                id: lane.id,
                                t_s: t,
                                kind: EventKind::Prefilled { prompt_len: prompt.len(), ttft_s: t },
                            });
                            let first = *tokens.last().expect("prefill sampled a token");
                            self.events.push_back(Event {
                                id: lane.id,
                                t_s: t,
                                kind: EventKind::Token {
                                    index: 0,
                                    token: first,
                                    text: self.tokenizer.decode(&[first]),
                                },
                            });
                        }
                        // TTFT is the Prefilled event's timestamp
                        self.ttfts.push(t);
                        if let Some(m) = &self.metrics {
                            m.ttft.observe(t);
                        }
                        lane.ttft_s = t;
                        lane.last_s = t;
                    }
                }
                self.check_deadline(&mut lane);
                self.active.push(lane);
                self.peak_active = self.peak_active.max(self.active.len());
            }
            self.evict_done();
        }
        if self.active.is_empty() {
            return Ok(true); // everything admitted finished instantly
        }

        // ---- one decode round: each active sequence advances a token,
        // all sequences stepping concurrently on the pool ----
        self.rounds += 1;
        if let Some(m) = &self.metrics {
            m.decode_rounds.inc();
        }
        let macs_before: u128 = if self.recorder.is_some() {
            self.active.iter().map(|l| l.macs).sum()
        } else {
            0
        };
        self.decode_round()?;
        if self.recorder.is_some() {
            let macs_after: u128 = self.active.iter().map(|l| l.macs).sum();
            self.trace(TraceEvent::DecodeRound {
                round,
                batch: self.active.len(),
                macs: macs_after - macs_before,
            });
        }
        // gather this round's (id, timestamp, token) in admission order —
        // a speculative lane may have emitted several tokens this round,
        // all sharing the round's timestamp (the first carries the
        // inter-token gap, the rest land at zero gap)
        let mut produced: Vec<(usize, f64, usize, i32, f64)> =
            Vec::with_capacity(self.active.len());
        let mut spec_rounds: Vec<(usize, usize, usize)> = Vec::new();
        for lane in &self.active {
            let LaneKind::Generate { tokens, spec, .. } = &lane.kind else {
                unreachable!("score lanes retire at admission")
            };
            let emitted = spec.as_ref().map_or(1, |s| s.round_emitted());
            let first = tokens.len() - emitted;
            for (j, &tok) in tokens[first..].iter().enumerate() {
                produced.push((
                    lane.id,
                    lane.step_t_s,
                    first + j,
                    tok,
                    if j == 0 { lane.last_s } else { lane.step_t_s },
                ));
            }
            if let Some(s) = spec {
                spec_rounds.push((lane.id, s.round_drafted(), s.round_accepted()));
            }
        }
        // causal-plane accounting for the speculative rounds: counts only
        // (the MACs are already inside this round's DecodeRound delta)
        for &(id, drafted, accepted) in &spec_rounds {
            self.spec_drafted += drafted;
            self.spec_accepted += accepted;
            self.spec_rejected += drafted - accepted;
            if let Some(m) = &self.metrics {
                m.spec_drafted.add(drafted as u64);
                m.spec_accepted.add(accepted as u64);
                m.spec_rejected.add((drafted - accepted) as u64);
            }
            self.trace(TraceEvent::SpecDrafted { id, round, k: drafted });
            self.trace(TraceEvent::SpecVerified {
                id,
                round,
                accepted,
                rejected: drafted - accepted,
            });
        }
        // …emit the Token events serially (deterministic order), deriving
        // inter-token latency from the event timestamps themselves…
        for &(id, t, index, token, prev_last) in &produced {
            if self.collect_events {
                let text = self.tokenizer.decode(&[token]);
                let kind = EventKind::Token { index, token, text };
                self.events.push_back(Event { id, t_s: t, kind });
            }
            self.itls.push(t - prev_last);
            if let Some(m) = &self.metrics {
                m.inter_token.observe(t - prev_last);
            }
        }
        // …then advance the lanes' clocks and apply deadlines
        for lane in &mut self.active {
            lane.last_s = lane.step_t_s;
            if lane.done.is_none() && lane.deadline_s.is_some_and(|d| lane.step_t_s > d) {
                lane.done = Some(FinishReason::Deadline);
            }
        }
        self.evict_done();
        Ok(true)
    }

    /// Step until idle, discarding no events (the caller drains them).
    pub fn drive(&mut self) -> Result<()> {
        while self.step()? {}
        Ok(())
    }

    /// Close the session: order undrained results by request id and
    /// aggregate stats. The stats cover the *whole* session — the tally
    /// and latency samples are recorded at retire time, so results
    /// already handed out via [`Session::drain_finished`] stay counted.
    pub fn finish(mut self) -> (Vec<FinishedRequest>, CoreStats) {
        let wall_s = self.now();
        self.finished.sort_by_key(|f| f.id);
        let stats = CoreStats {
            requests: self.tally.requests,
            batches: self.batches,
            scored_tokens: self.tally.scored_tokens,
            prompt_tokens: self.tally.prompt_tokens,
            generated_tokens: self.tally.generated_tokens,
            macs: self.tally.macs,
            recompute_macs: self.tally.recompute_macs,
            wall_s,
            latency: LatencySummary::from_unsorted(std::mem::take(&mut self.lats)),
            ttft: LatencySummary::from_unsorted(std::mem::take(&mut self.ttfts)),
            inter_token: LatencySummary::from_unsorted(std::mem::take(&mut self.itls)),
            peak_active: self.peak_active,
            mid_run_admissions: self.mid_run,
            decode_rounds: self.rounds,
            cancelled: self.cancelled,
            deadline_evictions: self.deadline_evictions,
            preemptions: self.preemptions,
            admitted_macs: self.admitted_macs,
            spec_drafted: self.spec_drafted,
            spec_accepted: self.spec_accepted,
            spec_rejected: self.spec_rejected,
            tenants: std::mem::take(&mut self.tenant_ledger),
        };
        (self.finished, stats)
    }

    // ---- internals -------------------------------------------------------

    /// Take a request out of the queue into a lane, building the KV pool
    /// on the first generation admission. The declared cost is folded
    /// into the admission meter and the tenant fairness ledger here —
    /// admission is the charge point.
    fn admit(&mut self, req: InferenceRequest, cost: RequestCost) -> Result<Lane> {
        let admitted = self.admitted_count;
        self.admitted_count += 1;
        // continuous batching: an admission after any slot retirement
        // means this request entered a slot another request freed mid-run
        if self.slot_retirements > 0 {
            self.mid_run += 1;
        }
        self.admitted_macs += cost.total_macs();
        let tenant = req.tenant.clone().unwrap_or_else(|| "-".to_string());
        let ledger = self.tenant_ledger.entry(tenant.clone()).or_default();
        ledger.requests += 1;
        ledger.declared_macs += cost.total_macs();
        let now = self.now();
        if let Some(m) = &self.metrics {
            m.admitted_macs.add(sat_u64(cost.total_macs()));
            m.tier_admissions.add(req.tier.name(), 1);
            m.tenant_requests.add(&tenant, 1);
            m.tenant_declared_macs.add(&tenant, sat_u64(cost.total_macs()));
            if self.slot_retirements > 0 {
                m.mid_run_admissions.inc();
            }
            if let Some(t) = self.submit_t.remove(&req.id) {
                m.queue_wait.observe(now - t);
            }
        }
        if self.collect_events {
            self.events.push_back(Event {
                id: req.id,
                t_s: now,
                kind: EventKind::Admitted { seq: admitted },
            });
        }
        let kind = match req.kind {
            RequestKind::Score { tokens } => LaneKind::Score { tokens, logits: Vec::new() },
            RequestKind::Generate { prompt, max_new } => {
                let cfg = self.core.config;
                let speculative = self.core.speculative();
                if self.pool.is_none() {
                    // both cache families (verifier + draft) are billed
                    // against the footprint cap before either allocates
                    let (pool, draft_pool) = KvCachePool::with_cap_dual(
                        self.core.model.config(),
                        cfg.slots.max(1),
                        cfg.capacity,
                        speculative,
                        cfg.max_cache_bytes,
                    )?;
                    self.pool = Some(pool);
                    self.draft_pool = draft_pool;
                }
                let cache = self
                    .pool
                    .as_mut()
                    .expect("pool just built")
                    .acquire()
                    .expect("free cache under the active-count bound");
                let spec = if speculative {
                    let draft = self.core.draft.expect("speculative() implies a draft model");
                    let draft_cache = self
                        .draft_pool
                        .as_mut()
                        .expect("dual pool built in speculative mode")
                        .acquire()
                        .expect("free draft cache under the active-count bound");
                    Some(Box::new(SpecState::new(
                        draft_cache,
                        draft.scratch(cfg.capacity.max(1)),
                        cfg.spec_k,
                    )))
                } else {
                    None
                };
                LaneKind::Generate {
                    max_new: max_new.unwrap_or(cfg.max_new).max(1),
                    rng: request_rng(cfg.seed, req.id),
                    scratch: self.core.model.scratch(cfg.capacity.max(1)),
                    prompt,
                    tokens: Vec::new(),
                    cache,
                    recompute_macs: 0,
                    spec,
                }
            }
        };
        Ok(Lane {
            id: req.id,
            admitted,
            deadline_s: req.deadline_s,
            tier: req.tier,
            macs: 0,
            ttft_s: 0.0,
            last_s: 0.0,
            step_t_s: 0.0,
            done: None,
            kind,
        })
    }

    /// Token-boundary preemption: when the batch tier has overspent its
    /// bucket (credit < 0 — impossible with an unlimited bucket) and
    /// admissible interactive work is queued with no free slot to take,
    /// the youngest active batch lanes are retired with
    /// [`FinishReason::Preempted`] (tokens kept, caches released) so the
    /// interactive requests admit this round. Pure counter arithmetic —
    /// no wall clock — so it is deterministic across thread counts.
    fn preempt_for_interactive(&mut self) {
        if !self.pending.batch_over_budget() {
            return;
        }
        let waiting = self.pending.admissible_interactive();
        let slots = self.core.config.slots.max(1);
        let free = slots.saturating_sub(self.active.len());
        let need = waiting.saturating_sub(free);
        if need == 0 {
            return;
        }
        // youngest batch lanes yield first (they have sunk the least
        // work); admission order makes the choice deterministic
        let mut victims: Vec<usize> = (0..self.active.len())
            .filter(|&i| self.active[i].tier == Tier::Batch && self.active[i].done.is_none())
            .collect();
        victims.sort_by_key(|&i| std::cmp::Reverse(self.active[i].admitted));
        victims.truncate(need);
        if victims.is_empty() {
            return;
        }
        // the interactive request the yielded slots admit this round —
        // guaranteed queued by the admissible_interactive() > free check
        let beneficiary = self
            .pending
            .first_admissible_interactive()
            .expect("preemption fires only with admissible interactive work queued");
        for i in victims {
            let victim = self.active[i].id;
            self.active[i].done = Some(FinishReason::Preempted);
            self.trace(TraceEvent::Preempted { victim, beneficiary, round: self.sched_rounds });
        }
        self.evict_done();
    }

    /// Forward every freshly admitted lane (score forwards and generation
    /// prefills) in parallel; deterministic because each worker writes
    /// only its own lanes and emission happens serially afterwards.
    fn forward_fresh(&mut self, fresh: &mut [Lane]) -> Result<()> {
        let model = self.core.model;
        let draft = self.core.draft;
        let (sampling, eos) = (self.core.config.sampling, self.core.config.eos);
        let threads = self.core.config.exec.resolve().max(1);
        let n_par = threads.min(fresh.len()).min(self.lane_cap()).max(1);
        let outer = ExecPool::new(n_par);
        let intra = ExecPool::new(threads).split(n_par);
        let t0 = &self.t0;
        let sink = self.metrics.clone();
        let items = fresh.len();
        outer.observe(sink.as_deref().map(|m| m as &dyn SpanObserver), "prefill", items, || {
            outer.try_parallel_for(fresh, |_, lane| -> Result<()> {
                let Lane { kind, macs, step_t_s, done, .. } = lane;
                match kind {
                    LaneKind::Score { tokens, logits } => {
                        let (l, m) = model.forward_logits_pooled(tokens, &intra)?;
                        *logits = l;
                        *macs = m;
                        *step_t_s = t0.elapsed().as_secs_f64();
                        *done = Some(FinishReason::Scored);
                    }
                    LaneKind::Generate {
                        prompt,
                        max_new,
                        tokens,
                        cache,
                        rng,
                        recompute_macs,
                        scratch,
                        spec,
                    } => {
                        let m = model.forward_prefill_scratch(prompt, cache, &intra, scratch)?;
                        let first = sampling.sample(&scratch.logits, rng);
                        *macs = m;
                        // the draft prefill is billed into the same lane MACs
                        // the PrefillDone trace reports, so the executed total
                        // stays reconstructable from the trace alone
                        if let (Some(draft), Some(spec)) = (draft, spec.as_mut()) {
                            *macs += spec.prefill(draft, prompt, &intra)?;
                        }
                        *recompute_macs = model.macs_for(prompt.len());
                        *step_t_s = t0.elapsed().as_secs_f64();
                        tokens.push(first);
                        *done = stop_reason(eos, first, tokens.len(), *max_new);
                    }
                }
                Ok(())
            })
        })
    }

    /// Advance every active generation lane by one token (or, on
    /// speculative lanes, one draft/verify round of one or more tokens).
    fn decode_round(&mut self) -> Result<()> {
        let model = self.core.model;
        let draft = self.core.draft;
        let spec_k = self.core.config.spec_k;
        let (sampling, eos) = (self.core.config.sampling, self.core.config.eos);
        let threads = self.core.config.exec.resolve().max(1);
        let n_par = threads.min(self.active.len()).min(self.lane_cap()).max(1);
        let outer = ExecPool::new(n_par);
        let intra = ExecPool::new(threads).split(n_par);
        let t0 = &self.t0;
        let sink = self.metrics.clone();
        let items = self.active.len();
        let active = &mut self.active;
        outer.observe(sink.as_deref().map(|m| m as &dyn SpanObserver), "decode", items, || {
            outer.try_parallel_for(active, |_, lane| -> Result<()> {
                let Lane { kind, macs, step_t_s, done, .. } = lane;
                let LaneKind::Generate {
                    prompt,
                    max_new,
                    tokens,
                    cache,
                    rng,
                    recompute_macs,
                    scratch,
                    spec,
                } = kind
                else {
                    unreachable!("score lanes retire at admission")
                };
                if let (Some(draft), Some(spec)) = (draft, spec.as_mut()) {
                    let out = spec_round(
                        model,
                        draft,
                        prompt.len(),
                        *max_new,
                        spec_k,
                        eos,
                        tokens,
                        cache,
                        spec,
                        scratch,
                        &intra,
                    )?;
                    *macs += out.macs;
                    for i in tokens.len() - out.emitted..tokens.len() {
                        *recompute_macs += model.macs_for(prompt.len() + i);
                    }
                    *step_t_s = t0.elapsed().as_secs_f64();
                    *done = if out.hit_eos {
                        Some(FinishReason::Eos)
                    } else if tokens.len() >= *max_new {
                        Some(FinishReason::MaxTokens)
                    } else {
                        None
                    };
                    return Ok(());
                }
                let last_tok = *tokens.last().expect("active sequences hold >= 1 token");
                let m = model.forward_step_scratch(last_tok, cache, &intra, scratch)?;
                *macs += m;
                *recompute_macs += model.macs_for(prompt.len() + tokens.len());
                let next = sampling.sample(&scratch.logits, rng);
                *step_t_s = t0.elapsed().as_secs_f64();
                tokens.push(next);
                *done = stop_reason(eos, next, tokens.len(), *max_new);
                Ok(())
            })
        })
    }

    /// The configured lane-parallelism cap (0 = unbounded).
    fn lane_cap(&self) -> usize {
        match self.core.config.lane_parallelism {
            0 => usize::MAX,
            n => n,
        }
    }

    /// Deadline sweep over the active lanes. Deadlines bind at *token
    /// boundaries* only — a queued request is never evicted while waiting
    /// and an admitted one always completes its prefill — so the
    /// smallest-possible deadline deterministically yields exactly one
    /// token, not a timing-dependent queue eviction.
    fn enforce_deadlines(&mut self) {
        let now = self.now();
        let mut any = false;
        for lane in &mut self.active {
            if lane.done.is_none() && lane.deadline_s.is_some_and(|d| now > d) {
                lane.done = Some(FinishReason::Deadline);
                any = true;
            }
        }
        if any {
            self.evict_done();
        }
    }

    /// Mark a lane past-deadline using its own phase timestamp (so the
    /// check is the same one the event timeline shows).
    fn check_deadline(&self, lane: &mut Lane) {
        if lane.done.is_none() && lane.deadline_s.is_some_and(|d| lane.step_t_s > d) {
            lane.done = Some(FinishReason::Deadline);
        }
    }

    /// Retire a request straight from the queue (never took a slot).
    fn retire_unadmitted(&mut self, req: InferenceRequest, reason: FinishReason) {
        let now = self.now();
        match reason {
            FinishReason::Cancelled => self.cancelled += 1,
            FinishReason::Deadline => self.deadline_evictions += 1,
            _ => {}
        }
        if let Some(m) = &self.metrics {
            match reason {
                FinishReason::Cancelled => m.cancelled.inc(),
                FinishReason::Deadline => m.deadline_evictions.inc(),
                _ => {}
            }
        }
        self.submit_t.remove(&req.id);
        self.trace(TraceEvent::Finished {
            id: req.id,
            round: self.sched_rounds,
            reason: reason.name(),
            tokens: 0,
        });
        if self.collect_events {
            self.events.push_back(Event {
                id: req.id,
                t_s: now,
                kind: EventKind::Finished { reason, tokens: 0 },
            });
        }
        self.record_finished(FinishedRequest {
            id: req.id,
            admitted: None,
            reason,
            is_generate: matches!(req.kind, RequestKind::Generate { .. }),
            prompt_len: req.prompt_len(),
            tokens: Vec::new(),
            text: String::new(),
            logits: Vec::new(),
            ttft_s: 0.0,
            latency_s: now,
            macs: 0,
            recompute_macs: 0,
        });
    }

    /// The one retirement sink: fold the request into the running tally
    /// (so drains can't lose it from the aggregate stats), sample its
    /// completion latency, and park it for the caller.
    fn record_finished(&mut self, f: FinishedRequest) {
        if let Some(m) = &self.metrics {
            // exact mirror of FinishTally::record — the self-check asserts
            // these counters equal the analytic accounting, not approximate
            m.requests.inc();
            m.executed_macs.add(sat_u64(f.macs));
            if f.is_generate {
                if f.admitted.is_some() {
                    m.prompt_tokens.add(f.prompt_len as u64);
                }
                m.generated_tokens.add(f.tokens.len() as u64);
            } else if f.reason == FinishReason::Scored {
                m.scored_tokens.add(f.prompt_len as u64);
            }
        }
        self.tally.record(&f);
        self.lats.push(f.latency_s);
        self.finished.push(f);
    }

    /// Move finished lanes out of the active set, releasing their caches
    /// and emitting their `Finished` events in admission order.
    fn evict_done(&mut self) {
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].done.is_some() {
                let lane = self.active.remove(i);
                self.retire_lane(lane);
            } else {
                i += 1;
            }
        }
    }

    fn retire_lane(&mut self, lane: Lane) {
        let reason = lane.done.expect("retire only done lanes");
        match reason {
            FinishReason::Cancelled => self.cancelled += 1,
            FinishReason::Deadline => self.deadline_evictions += 1,
            FinishReason::Preempted => self.preemptions += 1,
            _ => {}
        }
        if let Some(m) = &self.metrics {
            match reason {
                FinishReason::Cancelled => m.cancelled.inc(),
                FinishReason::Deadline => m.deadline_evictions.inc(),
                FinishReason::Preempted => m.preemptions.inc(),
                _ => {}
            }
        }
        self.slot_retirements += 1;
        let (is_generate, prompt_len, tokens, logits, recompute_macs) = match lane.kind {
            LaneKind::Score { tokens, logits } => {
                (false, tokens.len(), Vec::new(), logits, lane.macs)
            }
            LaneKind::Generate { prompt, tokens, cache, recompute_macs, spec, .. } => {
                self.pool.as_mut().expect("pool exists for generate lanes").release(cache);
                if let Some(s) = spec {
                    self.draft_pool
                        .as_mut()
                        .expect("draft pool exists for speculative lanes")
                        .release((*s).into_cache());
                }
                (true, prompt.len(), tokens, Vec::new(), recompute_macs)
            }
        };
        let produced = if is_generate { tokens.len() } else { prompt_len };
        self.trace(TraceEvent::Finished {
            id: lane.id,
            round: self.sched_rounds,
            reason: reason.name(),
            tokens: produced,
        });
        if self.collect_events {
            self.events.push_back(Event {
                id: lane.id,
                t_s: lane.last_s,
                kind: EventKind::Finished { reason, tokens: produced },
            });
        }
        let text = FinishedRequest::decode_text(&tokens);
        self.record_finished(FinishedRequest {
            id: lane.id,
            admitted: Some(lane.admitted),
            reason,
            is_generate,
            prompt_len,
            tokens,
            text,
            logits,
            ttft_s: lane.ttft_s,
            latency_s: lane.last_s,
            macs: lane.macs,
            recompute_macs,
        });
    }
}

/// The stopping rules after a token was appended.
fn stop_reason(
    eos: Option<i32>,
    token: i32,
    produced: usize,
    max_new: usize,
) -> Option<FinishReason> {
    if Some(token) == eos {
        Some(FinishReason::Eos)
    } else if produced >= max_new {
        Some(FinishReason::MaxTokens)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{demo_artifact, demo_config, ExecMode, ServeModel};

    fn model(seed: u64) -> ServeModel {
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, seed).unwrap();
        ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap()
    }

    fn gen_config(slots: usize) -> EngineConfig {
        EngineConfig {
            slots,
            capacity: 32,
            max_new: 6,
            seed: 7,
            eos: None,
            exec: ExecConfig::with_threads(2),
            ..EngineConfig::default()
        }
    }

    fn gen_requests(n: usize, prompt_len: usize) -> Vec<InferenceRequest> {
        crate::engine::synth_generate_requests(&demo_config(), n, prompt_len, 11)
    }

    /// Event-stream payloads of a driven session, per request id.
    fn drive_collect(
        core: &EngineCore,
        requests: Vec<InferenceRequest>,
    ) -> (Vec<Event>, Vec<FinishedRequest>, CoreStats) {
        let mut session = core.session();
        let mut queue: VecDeque<InferenceRequest> = requests.into();
        let mut events = Vec::new();
        loop {
            while let Some(req) = queue.pop_front() {
                if let Some(back) = session.try_submit(req).unwrap() {
                    queue.push_front(back);
                    break;
                }
            }
            let worked = session.step().unwrap();
            events.extend(session.take_events());
            if !worked && queue.is_empty() {
                break;
            }
        }
        let (finished, stats) = session.finish();
        (events, finished, stats)
    }

    #[test]
    fn streamed_token_events_equal_batch_results() {
        let m = model(41);
        let core = EngineCore::new(&m, gen_config(2));
        let (_, batch, _) = drive_collect(&core, gen_requests(5, 8));
        let (events, streamed, _) = drive_collect(&core, gen_requests(5, 8));
        assert_eq!(batch.len(), streamed.len());
        for (a, b) in batch.iter().zip(&streamed) {
            assert_eq!(a.tokens, b.tokens, "two drives of the same workload diverge");
        }
        // the concatenated Token payloads of each request equal its result
        for f in &streamed {
            let from_events: Vec<i32> = events
                .iter()
                .filter(|e| e.id == f.id)
                .filter_map(|e| match &e.kind {
                    EventKind::Token { token, .. } => Some(*token),
                    _ => None,
                })
                .collect();
            assert_eq!(from_events, f.tokens, "request {}", f.id);
            assert_eq!(f.text, FinishedRequest::decode_text(&f.tokens));
        }
        // per-request lifecycle order: Admitted, Prefilled, Token*, Finished
        for f in &streamed {
            let kinds: Vec<&EventKind> =
                events.iter().filter(|e| e.id == f.id).map(|e| &e.kind).collect();
            assert!(matches!(kinds[0], EventKind::Admitted { .. }), "request {}", f.id);
            assert!(matches!(kinds[1], EventKind::Prefilled { .. }));
            assert!(matches!(kinds.last().unwrap(), EventKind::Finished { .. }));
            assert_eq!(kinds.len(), 2 + f.tokens.len() + 1);
        }
    }

    #[test]
    fn event_order_is_invariant_across_thread_counts() {
        let m = model(43);
        let order = |threads: usize| {
            let config =
                EngineConfig { exec: ExecConfig::with_threads(threads), ..gen_config(2) };
            let core = EngineCore::new(&m, config);
            let (events, _, _) = drive_collect(&core, gen_requests(5, 6));
            // strip timestamps: (id, kind) must be bitwise stable
            events.into_iter().map(|e| (e.id, strip(e.kind))).collect::<Vec<_>>()
        };
        let serial = order(1);
        for threads in [2usize, 8] {
            assert_eq!(order(threads), serial, "--threads {threads} moved the event stream");
        }
    }

    #[test]
    fn speculative_session_matches_plain_greedy_and_counts_acceptance() {
        // the speculative engine path must be invisible in the output:
        // same requests, same greedy streams, same finish reasons — only
        // the acceptance counters betray that a draft model ran
        let cfg = demo_config();
        let verifier_cm = demo_artifact(&cfg, 0.8, 0x51EC).unwrap();
        let draft_cm = demo_artifact(&cfg, 0.35, 0x51EC).unwrap();
        let verifier = ServeModel::from_artifact(&verifier_cm, ExecMode::Factored).unwrap();
        let draft = ServeModel::from_artifact(&draft_cm, ExecMode::Factored).unwrap();
        let config = EngineConfig { max_new: 10, ..gen_config(2) };
        let plain = EngineCore::new(&verifier, config);
        let (_, baseline, base_stats) = drive_collect(&plain, gen_requests(4, 6));
        let spec_config = EngineConfig { spec_k: 3, ..config };
        let core = EngineCore::with_draft(&verifier, &draft, spec_config).unwrap();
        let (events, finished, stats) = drive_collect(&core, gen_requests(4, 6));
        assert_eq!(finished.len(), baseline.len());
        for (a, b) in baseline.iter().zip(&finished) {
            assert_eq!(a.tokens, b.tokens, "speculative stream diverged on request {}", a.id);
            assert_eq!(a.reason, b.reason);
        }
        // Token events still reconstruct each stream exactly
        for f in &finished {
            let from_events: Vec<i32> = events
                .iter()
                .filter(|e| e.id == f.id)
                .filter_map(|e| match &e.kind {
                    EventKind::Token { token, .. } => Some(*token),
                    _ => None,
                })
                .collect();
            assert_eq!(from_events, f.tokens, "request {}", f.id);
        }
        assert!(stats.spec_drafted > 0, "draft model never ran");
        assert_eq!(stats.spec_accepted + stats.spec_rejected, stats.spec_drafted);
        assert_eq!(stats.generated_tokens, base_stats.generated_tokens);
        assert_eq!(base_stats.spec_drafted, 0, "plain sessions draft nothing");
    }

    #[test]
    fn with_draft_rejects_inconsistent_configurations() {
        let cfg = demo_config();
        let verifier_cm = demo_artifact(&cfg, 0.8, 0x51EC).unwrap();
        let draft_cm = demo_artifact(&cfg, 0.35, 0x51EC).unwrap();
        let verifier = ServeModel::from_artifact(&verifier_cm, ExecMode::Factored).unwrap();
        let draft = ServeModel::from_artifact(&draft_cm, ExecMode::Factored).unwrap();
        let err = EngineCore::with_draft(&verifier, &draft, gen_config(1))
            .err()
            .expect("spec_k 0 with a draft bound must be rejected");
        assert!(err.to_string().contains("spec_k"), "{err}");
        let mut other = demo_config();
        other.d_ff += 8;
        let other_cm = demo_artifact(&other, 0.35, 0x51EC).unwrap();
        let other_draft = ServeModel::from_artifact(&other_cm, ExecMode::Factored).unwrap();
        let config = EngineConfig { spec_k: 2, ..gen_config(1) };
        let err = EngineCore::with_draft(&verifier, &other_draft, config)
            .err()
            .expect("mismatched checkpoint families must be rejected");
        assert!(err.to_string().contains("checkpoint"), "{err}");
    }

    /// Event kinds with the wall-clock field zeroed (payload comparison).
    fn strip(kind: EventKind) -> EventKind {
        match kind {
            EventKind::Prefilled { prompt_len, .. } => {
                EventKind::Prefilled { prompt_len, ttft_s: 0.0 }
            }
            other => other,
        }
    }

    #[test]
    fn cancel_queued_request_never_takes_a_slot() {
        // 1 slot, 2 requests: cancel the queued one while the first is
        // still decoding — "mid-prefill" cancellation, before admission
        let m = model(47);
        let core = EngineCore::new(&m, gen_config(1));
        let mut session = core.session();
        for r in gen_requests(2, 5) {
            session.submit(r).unwrap();
        }
        assert!(session.step().unwrap());
        assert_eq!(session.active_len(), 1, "one slot admits one request");
        assert_eq!(session.pending_len(), 1);
        assert!(session.cancel(1), "queued request is cancellable");
        assert!(!session.cancel(1), "second cancel is a no-op");
        session.drive().unwrap();
        let (finished, stats) = session.finish();
        assert_eq!(finished.len(), 2);
        assert_eq!(finished[0].reason, FinishReason::MaxTokens);
        assert_eq!(finished[1].reason, FinishReason::Cancelled);
        assert!(finished[1].tokens.is_empty(), "cancelled before any token");
        assert_eq!(finished[1].admitted, None, "never granted a slot");
        assert_eq!(stats.cancelled, 1);
    }

    #[test]
    fn cancel_mid_decode_frees_the_slot_for_the_queue() {
        // 1 slot, 2 requests: cancel the active one after its first
        // tokens — the queued request must be admitted into the freed slot
        let m = model(53);
        let core = EngineCore::new(&m, gen_config(1));
        let mut session = core.session();
        for r in gen_requests(2, 5) {
            session.submit(r).unwrap();
        }
        session.step().unwrap(); // request 0 admitted + prefilled + 1 round
        assert!(session.cancel(0), "active request is cancellable");
        session.drive().unwrap();
        let (finished, stats) = session.finish();
        assert_eq!(finished[0].reason, FinishReason::Cancelled);
        assert!(
            !finished[0].tokens.is_empty() && finished[0].tokens.len() < 6,
            "cancelled mid-decode keeps a partial stream ({} tokens)",
            finished[0].tokens.len()
        );
        assert_eq!(finished[1].reason, FinishReason::MaxTokens);
        assert_eq!(finished[1].tokens.len(), 6, "queued request ran to its budget");
        assert_eq!(finished[1].admitted, Some(1), "admitted into the freed slot");
        assert_eq!(stats.mid_run_admissions, 1);
        assert_eq!(stats.cancelled, 1);
    }

    #[test]
    fn deadline_eviction_frees_the_slot_for_a_queued_request() {
        // 1 slot: the first request's deadline expires right after its
        // prefill (any positive wall-clock beats 1e-9 s), evicting it and
        // admitting the queued request into the freed slot
        let m = model(59);
        let core = EngineCore::new(&m, gen_config(1));
        let mut reqs = gen_requests(2, 5);
        reqs[0].deadline_s = Some(1e-9);
        let (finished, stats) = core.run(reqs).unwrap();
        assert_eq!(finished[0].reason, FinishReason::Deadline);
        assert_eq!(finished[0].tokens.len(), 1, "keeps the prefill token, steps no further");
        assert_eq!(finished[1].reason, FinishReason::MaxTokens);
        assert_eq!(finished[1].admitted, Some(1), "queued request reused the slot");
        assert_eq!(stats.deadline_evictions, 1);
        assert_eq!(stats.mid_run_admissions, 1);
    }

    #[test]
    fn expired_requests_still_get_their_prefill() {
        // deadlines bind at token boundaries: even an already-expired
        // request is admitted, prefills once, and leaves with exactly one
        // token — deterministically, for any wall-clock timing
        let m = model(61);
        let core = EngineCore::new(&m, gen_config(1));
        let mut reqs = gen_requests(2, 5);
        reqs[0].deadline_s = Some(0.0);
        reqs[1].deadline_s = Some(0.0);
        let (finished, stats) = core.run(reqs).unwrap();
        for f in &finished {
            assert_eq!(f.reason, FinishReason::Deadline);
            assert_eq!(f.tokens.len(), 1, "request {}", f.id);
            assert!(f.admitted.is_some(), "expired requests still take their turn");
        }
        assert_eq!(stats.deadline_evictions, 2);
        assert_eq!(stats.mid_run_admissions, 1, "the freed slot served the queue");
    }

    #[test]
    fn bounded_queue_applies_backpressure() {
        let m = model(67);
        let config = EngineConfig { queue_cap: 2, ..gen_config(1) };
        let core = EngineCore::new(&m, config);
        let mut session = core.session();
        let mut reqs = gen_requests(4, 5);
        assert_eq!(session.queue_free(), 2);
        assert!(session.try_submit(reqs.remove(0)).unwrap().is_none());
        assert!(session.try_submit(reqs.remove(0)).unwrap().is_none());
        // third submission bounces back instead of buffering
        let bounced = session.try_submit(reqs.remove(0)).unwrap();
        assert!(bounced.is_some(), "full queue hands the request back");
        assert_eq!(bounced.as_ref().unwrap().id, 2);
        assert!(session.submit(bounced.unwrap()).is_err(), "submit() surfaces it as an Err");
        // a step admits one into the slot, freeing queue room
        session.step().unwrap();
        assert!(session.try_submit(reqs.remove(0)).unwrap().is_none());
        session.drive().unwrap();
        let (finished, _) = session.finish();
        assert_eq!(finished.len(), 3, "the bounced request was dropped by this driver");
    }

    #[test]
    fn invalid_and_duplicate_submissions_are_rejected() {
        let m = model(71);
        let core = EngineCore::new(&m, gen_config(2));
        let mut session = core.session();
        assert!(session.try_submit(InferenceRequest::generate(0, Vec::new(), None)).is_err());
        assert!(session
            .try_submit(InferenceRequest::generate(0, vec![1; 40], None))
            .is_err(), "prompt + max_new > capacity");
        assert!(session.try_submit(InferenceRequest::score(0, Vec::new())).is_err());
        session.submit(InferenceRequest::generate(0, vec![1, 2], None)).unwrap();
        assert!(session.submit(InferenceRequest::generate(0, vec![3], None)).is_err(), "dup id");
    }

    #[test]
    fn mixed_score_and_generate_requests_share_one_session() {
        let m = model(73);
        let core = EngineCore::new(&m, gen_config(2));
        let prompts = crate::engine::synth_token_streams(&demo_config(), 4, 6, 19);
        let reqs: Vec<InferenceRequest> = prompts
            .iter()
            .enumerate()
            .map(|(id, p)| {
                if id % 2 == 0 {
                    InferenceRequest::score(id, p.clone())
                } else {
                    InferenceRequest::generate(id, p.clone(), Some(3))
                }
            })
            .collect();
        let (finished, stats) = core.run(reqs).unwrap();
        assert_eq!(finished.len(), 4);
        let vocab = demo_config().vocab;
        for f in &finished {
            if f.id % 2 == 0 {
                assert_eq!(f.reason, FinishReason::Scored);
                assert!(!f.is_generate);
                assert_eq!(f.logits.len(), 6 * vocab);
                assert!(f.tokens.is_empty());
                let (want, want_macs) = m.forward_logits(&prompts[f.id]).unwrap();
                assert_eq!(f.logits, want, "scored logits == plain forward");
                assert_eq!(f.macs, want_macs);
            } else {
                assert_eq!(f.reason, FinishReason::MaxTokens);
                assert!(f.is_generate);
                assert_eq!(f.tokens.len(), 3);
                assert!(f.logits.is_empty());
            }
        }
        assert_eq!(stats.scored_tokens, 2 * 6);
        assert_eq!(stats.generated_tokens, 2 * 3);
        assert_eq!(stats.requests, 4);
        assert!(stats.request_stats().tokens == stats.scored_tokens + stats.generated_tokens);
    }

    #[test]
    fn snapshot_tracks_a_running_session() {
        // 1 slot, queue_cap 2, 3 requests: the snapshot must show the
        // occupancy at every stage of the run, and the totals at the end
        let m = model(79);
        let config = EngineConfig { queue_cap: 2, ..gen_config(1) };
        let core = EngineCore::new(&m, config);
        let mut session = core.session();
        let fresh = session.snapshot();
        assert_eq!(fresh, EngineSnapshot { queue_cap: 2, slots: 1, free_slots: 1, ..fresh });
        assert_eq!((fresh.queue_depth, fresh.active, fresh.finished), (0, 0, 0));

        let mut reqs = gen_requests(3, 5);
        session.submit(reqs.remove(0)).unwrap();
        session.submit(reqs.remove(0)).unwrap();
        let queued = session.snapshot();
        assert_eq!((queued.queue_depth, queued.active, queued.free_slots), (2, 0, 1));
        assert_eq!(queued.queue_depth, queued.queue_cap, "shedding condition reached");

        session.step().unwrap(); // admit one into the lone slot
        let running = session.snapshot();
        assert_eq!((running.queue_depth, running.active, running.free_slots), (1, 1, 0));
        assert_eq!(running.admitted, 1);
        assert!(session.try_submit(reqs.remove(0)).unwrap().is_none(), "queue has room again");

        session.drive().unwrap();
        let done = session.snapshot();
        assert_eq!((done.queue_depth, done.active, done.free_slots), (0, 0, 1));
        let (finished, stats) = session.finish();
        assert_eq!(done.finished, finished.len());
        assert_eq!(done.admitted, 3);
        assert_eq!(done.generated_tokens, stats.generated_tokens);
        assert_eq!(done.macs, stats.macs);
        assert_eq!(done.decode_rounds, stats.decode_rounds);
        assert_eq!(done.mid_run_admissions, stats.mid_run_admissions);
    }

    #[test]
    fn default_config_reduces_exactly_to_fifo() {
        // the FIFO-reduction bar, asserted: single tier + no deadlines +
        // unlimited meter ⇒ admission seq == submission order, for every
        // slot count
        let m = model(89);
        for slots in [1usize, 2, 4] {
            let core = EngineCore::new(&m, gen_config(slots));
            let (finished, stats) = core.run(gen_requests(6, 5)).unwrap();
            for (i, f) in finished.iter().enumerate() {
                assert_eq!(f.admitted, Some(i), "slots {slots}: request {} left FIFO order", f.id);
            }
            assert_eq!(stats.preemptions, 0, "default config must never preempt");
        }
    }

    #[test]
    fn earliest_deadline_first_reorders_admission() {
        // 1 slot, deadlines in reverse arrival order: admission must
        // follow the deadlines, not arrival. Deadlines far in the future
        // (1e6 s) order the queue without ever expiring.
        let m = model(97);
        let core = EngineCore::new(&m, gen_config(1));
        let mut reqs = gen_requests(3, 5);
        reqs[0].deadline_s = Some(3e6);
        reqs[1].deadline_s = Some(2e6);
        reqs[2].deadline_s = Some(1e6);
        let (finished, _) = core.run(reqs).unwrap();
        assert_eq!(finished[0].admitted, Some(2));
        assert_eq!(finished[1].admitted, Some(1));
        assert_eq!(finished[2].admitted, Some(0), "tightest deadline admits first");
        for f in &finished {
            assert_eq!(f.reason, FinishReason::MaxTokens, "no deadline ever expired");
        }
    }

    #[test]
    fn interactive_tier_outranks_batch_in_the_queue() {
        // 1 slot, everything queued up-front: the interactive request
        // overtakes the three batch requests submitted before it
        let m = model(101);
        let core = EngineCore::new(&m, gen_config(1));
        let mut reqs = gen_requests(4, 5);
        reqs[3].tier = Tier::Interactive;
        let (finished, _) = core.run(reqs).unwrap();
        assert_eq!(finished[3].admitted, Some(0), "interactive overtakes the batch queue");
        assert_eq!(finished[0].admitted, Some(1), "then arrival order resumes");
        assert_eq!(finished[1].admitted, Some(2));
        assert_eq!(finished[2].admitted, Some(3));
    }

    #[test]
    fn over_budget_batch_work_is_preempted_for_interactive() {
        // a 1-MAC batch bucket: the first batch admission overdraws it
        // deeply, so while that lane holds the only slot, a queued
        // interactive request forces a token-boundary preemption
        let m = model(103);
        let config = EngineConfig { batch_macs_per_round: 1, ..gen_config(1) };
        let core = EngineCore::new(&m, config);
        let mut session = core.session();
        let mut reqs = gen_requests(3, 5);
        reqs[2].tier = Tier::Interactive;
        let (batch_a, batch_b, interactive) =
            (reqs.remove(0), reqs.remove(0), reqs.remove(0));
        session.submit(batch_a).unwrap();
        session.step().unwrap(); // credit 1 > 0 admits it, then deep deficit
        assert_eq!(session.active_len(), 1);
        session.submit(batch_b).unwrap();
        session.step().unwrap(); // batch throttled: request 1 waits
        assert_eq!(session.active_len(), 1, "over-budget batch tier admits nothing");
        assert_eq!(session.pending_len(), 1);
        session.submit(interactive).unwrap();
        session.drive().unwrap();
        let (finished, stats) = session.finish();
        assert_eq!(stats.preemptions, 1, "interactive arrival preempted the batch lane");
        assert_eq!(finished[0].reason, FinishReason::Preempted);
        assert!(
            !finished[0].tokens.is_empty() && finished[0].tokens.len() < 6,
            "preempted at a token boundary keeps a partial stream ({} tokens)",
            finished[0].tokens.len()
        );
        assert_eq!(finished[2].reason, FinishReason::MaxTokens);
        assert_eq!(finished[2].tokens.len(), 6, "interactive ran to its budget");
        assert_eq!(finished[2].admitted, Some(1), "admitted into the preempted slot");
        // once the engine idles, the throttled batch request gets in via
        // the work-conserving guarantee rather than waiting out a deficit
        // that repays 1 MAC per round
        assert_eq!(finished[1].reason, FinishReason::MaxTokens);
        assert_eq!(finished[1].tokens.len(), 6);
    }

    #[test]
    fn admission_meter_and_tenant_ledger_record_declared_costs() {
        let m = model(107);
        let core = EngineCore::new(&m, gen_config(2));
        let mut reqs = gen_requests(4, 5);
        reqs[0].tenant = Some("acme".to_string());
        reqs[1].tenant = Some("acme".to_string());
        reqs[2].tenant = Some("beta".to_string());
        // reqs[3] stays anonymous → the "-" row
        let (_, stats) = core.run(reqs).unwrap();
        // the meter equals the sum of per-request worst-case prices:
        // every request here is Generate{prompt: 5, max_new: None} with
        // config max_new 6
        let cm = crate::model::macs::CostModel::new(m.config(), m.macs_for(1));
        let per_req = cm.generate(5, 6).total_macs();
        assert_eq!(stats.admitted_macs, 4 * per_req);
        assert_eq!(stats.tenants.len(), 3);
        assert_eq!(stats.tenants["acme"], TenantUsage { requests: 2, declared_macs: 2 * per_req });
        assert_eq!(stats.tenants["beta"], TenantUsage { requests: 1, declared_macs: per_req });
        assert_eq!(stats.tenants["-"], TenantUsage { requests: 1, declared_macs: per_req });
    }

    #[test]
    fn mac_denominated_queue_cap_sheds_by_price() {
        let m = model(109);
        let cm = crate::model::macs::CostModel::new(m.config(), m.macs_for(1));
        let per_req = cm.generate(5, 6).total_macs();
        // room for exactly two queued requests' declared MACs
        let config =
            EngineConfig { max_queued_macs: 2 * per_req, ..gen_config(1) };
        let core = EngineCore::new(&m, config);
        let mut session = core.session();
        let mut reqs = gen_requests(3, 5);
        assert!(session.try_submit(reqs.remove(0)).unwrap().is_none());
        assert!(session.try_submit(reqs.remove(0)).unwrap().is_none());
        assert_eq!(session.snapshot().queued_macs, 2 * per_req);
        let bounced = session.try_submit(reqs.remove(0)).unwrap();
        assert!(bounced.is_some(), "a third declared cost exceeds the MAC bound");
        // a step admits one into the slot, freeing metered room
        session.step().unwrap();
        assert_eq!(session.snapshot().queued_macs, per_req);
        assert!(session.try_submit(bounced.unwrap()).unwrap().is_none());
        session.drive().unwrap();
        let (finished, _) = session.finish();
        assert_eq!(finished.len(), 3);
        assert_eq!(session_queued(&finished), 0);
    }

    /// Helper keeping the MAC-cap test readable: nothing left queued.
    fn session_queued(finished: &[FinishedRequest]) -> usize {
        finished.iter().filter(|f| f.admitted.is_none()).count()
    }

    #[test]
    fn drain_finished_hands_out_results_without_losing_stats() {
        // drain after every step: the incremental results must equal the
        // undriven batch run, and finish() must still report the whole
        // session's stats even though its finished list is empty
        let m = model(83);
        let core = EngineCore::new(&m, gen_config(2));
        let (batch, batch_stats) = core.run(gen_requests(4, 5)).unwrap();

        let mut session = core.session();
        let mut queue: VecDeque<InferenceRequest> = gen_requests(4, 5).into();
        let mut drained: Vec<FinishedRequest> = Vec::new();
        loop {
            while let Some(req) = queue.pop_front() {
                if let Some(back) = session.try_submit(req).unwrap() {
                    queue.push_front(back);
                    break;
                }
            }
            let worked = session.step().unwrap();
            drained.extend(session.drain_finished());
            assert_eq!(session.snapshot().finished, drained.len(), "tally survives drains");
            if !worked && queue.is_empty() {
                break;
            }
        }
        let (leftover, stats) = session.finish();
        assert!(leftover.is_empty(), "every result was drained early");
        drained.sort_by_key(|f| f.id);
        assert_eq!(drained.len(), batch.len());
        for (a, b) in drained.iter().zip(&batch) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "request {}", a.id);
            assert_eq!(a.reason, b.reason);
        }
        assert_eq!(stats.requests, batch_stats.requests);
        assert_eq!(stats.generated_tokens, batch_stats.generated_tokens);
        assert_eq!(stats.prompt_tokens, batch_stats.prompt_tokens);
        assert_eq!(stats.macs, batch_stats.macs);
        assert_eq!(stats.latency.n, batch_stats.latency.n, "latency samples survive drains");
    }
}
