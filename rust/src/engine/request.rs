//! The unified request vocabulary of the streaming inference core: what a
//! request asks ([`InferenceRequest`]), what the engine tells the caller
//! while it runs ([`Event`]), and what comes back when it is done
//! ([`FinishedRequest`], [`FinishReason`]).
//!
//! Both legacy front-end request types convert losslessly into
//! [`InferenceRequest`] (`From<ServeRequest>` / `From<GenRequest>`), which
//! is how the serve and decode adapters feed the shared core without
//! changing their public `run()` signatures.

use crate::data::Tokenizer;
use crate::decode::GenRequest;
use crate::serve::ServeRequest;

/// What a request asks of the model.
#[derive(Debug, Clone)]
pub enum RequestKind {
    /// Forward the tokens once and return per-position logits — the serve
    /// path ([`crate::serve::ServeEngine`]).
    Score {
        /// Prompt token ids (non-empty, in-vocab).
        tokens: Vec<i32>,
    },
    /// KV-cached autoregressive generation from the prompt — the decode
    /// path ([`crate::decode::DecodeScheduler`]).
    Generate {
        /// Prompt token ids (non-empty, in-vocab).
        prompt: Vec<i32>,
        /// Per-request generation cap; `None` uses
        /// [`super::EngineConfig::max_new`].
        max_new: Option<usize>,
    },
}

/// Scheduling tier of a request — which token bucket meters it and how it
/// ranks against equal-deadline peers in the priced scheduler
/// ([`crate::engine::Scheduler`]). The default is [`Tier::Batch`], so every
/// caller that predates the scheduler is unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Tier {
    /// Latency-sensitive work: ranks ahead of Batch at equal deadline and
    /// can trigger token-boundary preemption of over-budget batch lanes.
    Interactive,
    /// Throughput work (the default): metered first, preemptible when its
    /// bucket runs dry while interactive work waits.
    Batch,
}

impl Default for Tier {
    fn default() -> Self {
        Tier::Batch
    }
}

impl Tier {
    pub fn name(self) -> &'static str {
        match self {
            Tier::Interactive => "interactive",
            Tier::Batch => "batch",
        }
    }

    /// Deterministic ordering rank: Interactive before Batch.
    pub(crate) fn rank(self) -> u8 {
        match self {
            Tier::Interactive => 0,
            Tier::Batch => 1,
        }
    }
}

/// One request submitted to the engine core.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: usize,
    pub kind: RequestKind,
    /// Wall-clock budget in seconds, relative to session start. A request
    /// still unfinished when it expires is evicted with
    /// [`FinishReason::Deadline`], keeping whatever tokens it produced.
    /// Deadlines bind at token boundaries: an admitted request always
    /// completes its prefill, so even an already-expired request yields
    /// deterministically exactly one token. Deadlines also drive queue
    /// *order*: the scheduler admits earliest-deadline-first.
    pub deadline_s: Option<f64>,
    /// Scheduling tier ([`Tier::Batch`] unless set) — selects the token
    /// bucket that meters this request's declared MAC cost.
    pub tier: Tier,
    /// Fairness-ledger key: admissions and declared MACs are tallied per
    /// tenant in [`crate::engine::CoreStats::tenants`]. `None` bills the
    /// anonymous ledger row `"-"`.
    pub tenant: Option<String>,
}

impl InferenceRequest {
    /// A scoring (full-forward) request.
    pub fn score(id: usize, tokens: Vec<i32>) -> InferenceRequest {
        InferenceRequest {
            id,
            kind: RequestKind::Score { tokens },
            deadline_s: None,
            tier: Tier::Batch,
            tenant: None,
        }
    }

    /// A generation request.
    pub fn generate(id: usize, prompt: Vec<i32>, max_new: Option<usize>) -> InferenceRequest {
        InferenceRequest {
            id,
            kind: RequestKind::Generate { prompt, max_new },
            deadline_s: None,
            tier: Tier::Batch,
            tenant: None,
        }
    }

    /// Attach a deadline (seconds from session start).
    pub fn with_deadline(mut self, deadline_s: f64) -> InferenceRequest {
        self.deadline_s = Some(deadline_s);
        self
    }

    /// Set the scheduling tier (default [`Tier::Batch`]).
    pub fn with_tier(mut self, tier: Tier) -> InferenceRequest {
        self.tier = tier;
        self
    }

    /// Set the tenant the fairness ledger bills this request to.
    pub fn with_tenant(mut self, tenant: impl Into<String>) -> InferenceRequest {
        self.tenant = Some(tenant.into());
        self
    }

    /// Prompt length in tokens, for either kind.
    pub fn prompt_len(&self) -> usize {
        match &self.kind {
            RequestKind::Score { tokens } => tokens.len(),
            RequestKind::Generate { prompt, .. } => prompt.len(),
        }
    }
}

impl From<ServeRequest> for InferenceRequest {
    fn from(r: ServeRequest) -> InferenceRequest {
        InferenceRequest::score(r.id, r.tokens)
    }
}

impl From<GenRequest> for InferenceRequest {
    fn from(r: GenRequest) -> InferenceRequest {
        InferenceRequest {
            id: r.id,
            kind: RequestKind::Generate { prompt: r.prompt, max_new: r.max_new },
            deadline_s: r.deadline_s,
            tier: Tier::Batch,
            tenant: None,
        }
    }
}

/// Why a request left the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// The configured end-of-sequence token was sampled (it is included as
    /// the last generated token).
    Eos,
    /// The request's token budget was reached.
    MaxTokens,
    /// A scoring request completed its forward.
    Scored,
    /// The caller cancelled the request mid-flight; tokens produced so far
    /// are kept and its slot was freed for the queue.
    Cancelled,
    /// The request's deadline expired before it finished; tokens produced
    /// so far are kept and its slot was freed for the queue.
    Deadline,
    /// The scheduler preempted an over-budget batch lane at a token
    /// boundary to free its slot for waiting interactive work; tokens
    /// produced so far are kept.
    Preempted,
}

impl FinishReason {
    pub fn name(self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::MaxTokens => "max-tokens",
            FinishReason::Scored => "scored",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Deadline => "deadline",
            FinishReason::Preempted => "preempted",
        }
    }
}

/// One entry of a request's event stream. Event *order and payloads* are
/// deterministic (invariant to `--threads`, slot timing, and admission
/// interleaving); only the timestamps carry wall-clock noise.
#[derive(Debug, Clone)]
pub struct Event {
    /// The request this event belongs to.
    pub id: usize,
    /// Seconds since session start — TTFT/inter-token stats are derived
    /// from exactly these timestamps.
    pub t_s: f64,
    pub kind: EventKind,
}

/// The lifecycle alphabet: `Admitted → (Prefilled → Token*)? → Finished`.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// The request left the queue and took a slot; `seq` is the admission
    /// order — the scheduler's (deadline, tier, arrival) pick order, which
    /// reduces to submission order under a single tier with no deadlines.
    Admitted { seq: usize },
    /// Generation only: the prompt was prefilled and the first token
    /// sampled. `ttft_s` equals this event's timestamp — queue wait plus
    /// prefill, the time-to-first-token.
    Prefilled { prompt_len: usize, ttft_s: f64 },
    /// One generated token. `index` counts from 0 per request; `text` is
    /// the token's decoded text ("" for special tokens).
    Token { index: usize, token: i32, text: String },
    /// The request is done; `tokens` is what it produced (generated
    /// tokens, or scored prompt positions for [`FinishReason::Scored`]).
    Finished { reason: FinishReason, tokens: usize },
}

/// A streaming callback's verdict after each event — returned from the
/// `on_event` hook of [`crate::engine::EngineCore::run_streaming`] /
/// [`crate::decode::DecodeScheduler::run_streaming`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamControl {
    Continue,
    /// Cancel the request this event belongs to. Applied at the next
    /// scheduling-step boundary: the partial stream is kept (reason
    /// `Cancelled`) and the slot freed. A request's first step yields two
    /// tokens (prefill + first round), so cancelling on the very first
    /// `Token` event still keeps two tokens.
    Cancel,
}

/// The completed-request record the session hands back — the superset of
/// [`crate::serve::ServeResult`] and [`crate::decode::GenResult`], which
/// the adapters project out.
#[derive(Debug, Clone)]
pub struct FinishedRequest {
    pub id: usize,
    /// Admission sequence number; `None` for a request cancelled straight
    /// from the queue, before it ever took a slot (deadlines, by contrast,
    /// bind only after admission — see [`InferenceRequest::deadline_s`]).
    pub admitted: Option<usize>,
    pub reason: FinishReason,
    /// Whether this was a generation request (false = scoring).
    pub is_generate: bool,
    pub prompt_len: usize,
    /// Generated tokens (empty for scoring requests).
    pub tokens: Vec<i32>,
    /// Decoded text of `tokens` (specials skipped).
    pub text: String,
    /// `(seq, vocab)` logits for scoring requests (empty for generation).
    pub logits: Vec<f32>,
    /// Run start → first token (0 when no token was produced).
    pub ttft_s: f64,
    /// Run start → finished.
    pub latency_s: f64,
    /// MACs executed for this request.
    pub macs: u128,
    /// Analytic MACs a cache-less recompute of the same stream would
    /// execute (equals `macs` for scoring requests).
    pub recompute_macs: u128,
}

impl FinishedRequest {
    /// Decode a token stream with the byte-level tokenizer (the engine's
    /// one text convention, shared by `Event::Token.text`).
    pub(crate) fn decode_text(tokens: &[i32]) -> String {
        Tokenizer::new().decode(tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_preserve_payloads() {
        let s = ServeRequest { id: 3, tokens: vec![1, 2, 3] };
        let r = InferenceRequest::from(s);
        assert_eq!(r.id, 3);
        assert_eq!(r.prompt_len(), 3);
        assert!(matches!(r.kind, RequestKind::Score { .. }));
        assert!(r.deadline_s.is_none());
        assert_eq!(r.tier, Tier::Batch);
        assert!(r.tenant.is_none());

        let g = GenRequest { id: 7, prompt: vec![4, 5], max_new: Some(9), deadline_s: Some(0.5) };
        let r = InferenceRequest::from(g);
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt_len(), 2);
        assert_eq!(r.deadline_s, Some(0.5));
        assert_eq!(r.tier, Tier::Batch);
        assert!(r.tenant.is_none());
        match r.kind {
            RequestKind::Generate { ref prompt, max_new } => {
                assert_eq!(prompt, &vec![4, 5]);
                assert_eq!(max_new, Some(9));
            }
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn finish_reason_names_cover_all_variants() {
        let all = [
            FinishReason::Eos,
            FinishReason::MaxTokens,
            FinishReason::Scored,
            FinishReason::Cancelled,
            FinishReason::Deadline,
            FinishReason::Preempted,
        ];
        let names: Vec<&str> = all.iter().map(|r| r.name()).collect();
        assert_eq!(
            names,
            ["eos", "max-tokens", "scored", "cancelled", "deadline", "preempted"]
        );
    }

    #[test]
    fn deadline_builder_attaches() {
        let r = InferenceRequest::generate(0, vec![1], None).with_deadline(2.5);
        assert_eq!(r.deadline_s, Some(2.5));
    }

    #[test]
    fn tier_and_tenant_builders_attach() {
        let r = InferenceRequest::generate(0, vec![1], None)
            .with_tier(Tier::Interactive)
            .with_tenant("acme");
        assert_eq!(r.tier, Tier::Interactive);
        assert_eq!(r.tenant.as_deref(), Some("acme"));
        assert_eq!(Tier::default(), Tier::Batch);
        assert_eq!(Tier::Interactive.name(), "interactive");
        assert_eq!(Tier::Batch.name(), "batch");
        assert!(Tier::Interactive.rank() < Tier::Batch.rank());
    }
}
