//! The priced, tiered admission scheduler — weight-metered scheduling in
//! the analytic-cost currency of [`crate::model::macs`].
//!
//! Every queued request carries a [`RequestCost`] declared *before* it
//! runs (prefill + worst-case decode MACs, peak KV bytes — the paper's §2
//! accounting applied per request). [`Scheduler`] replaces the engine
//! core's FIFO `VecDeque` with:
//!
//! - **Earliest-deadline-first ordering**: the queue is kept sorted by
//!   `(deadline, tier, arrival)` — deadline-less requests sort last
//!   (+∞), [`Tier::Interactive`] ranks before [`Tier::Batch`] at equal
//!   deadline, and arrival order breaks the remaining ties. A single
//!   tier with no deadlines therefore reduces *exactly* to FIFO.
//! - **Per-tier token buckets**: each tier holds a MAC budget refilled
//!   once per scheduling round ([`Scheduler::begin_round`]); a request is
//!   admissible only while its tier's bucket has credit, and admission
//!   charges the declared cost (deficit-style: credit may go negative,
//!   which throttles the tier for the following rounds instead of
//!   rejecting work — deterministic and starvation-free). A refill of 0
//!   means unlimited, the default, under which admission is unmetered
//!   and order-identical to FIFO.
//!
//! Everything here is a pure function of (arrival order, declared cost,
//! tier, deadline) — no wall clock — so scheduling decisions are bitwise
//! invariant to `--threads` and to timing.

use std::cmp::Ordering;

use crate::model::macs::RequestCost;

use super::request::{InferenceRequest, Tier};

/// One MAC-denominated token bucket.
#[derive(Debug, Clone, Copy)]
struct Bucket {
    /// Credit added per scheduling round; 0 = unlimited (never metered).
    refill: u128,
    /// Remaining credit; negative = in deficit (tier throttled until the
    /// round refills pay it back).
    credit: i128,
}

impl Bucket {
    fn new(refill: u128) -> Bucket {
        let refill_i = i128::try_from(refill).unwrap_or(i128::MAX);
        Bucket { refill, credit: refill_i }
    }

    fn admissible(&self) -> bool {
        self.refill == 0 || self.credit > 0
    }

    fn charge(&mut self, macs: u128) {
        if self.refill != 0 {
            let macs_i = i128::try_from(macs).unwrap_or(i128::MAX);
            self.credit = self.credit.saturating_sub(macs_i);
        }
    }

    fn begin_round(&mut self) {
        if self.refill != 0 {
            let refill_i = i128::try_from(self.refill).unwrap_or(i128::MAX);
            // deficit carry-over: credit climbs back by one refill per
            // round, capped at one full bucket (no unbounded hoarding)
            self.credit = self.credit.saturating_add(refill_i).min(refill_i);
        }
    }

    fn over_budget(&self) -> bool {
        self.refill != 0 && self.credit < 0
    }
}

/// A queued request with its declared price and arrival stamp.
#[derive(Debug, Clone)]
struct Entry {
    /// Arrival order within this session (the FIFO tie-breaker).
    seq: u64,
    cost: RequestCost,
    req: InferenceRequest,
}

impl Entry {
    /// The deterministic scheduling key: `(deadline, tier, arrival)`.
    fn key(&self) -> (f64, u8, u64) {
        (self.req.deadline_s.unwrap_or(f64::INFINITY), self.req.tier.rank(), self.seq)
    }

    fn cmp_key(&self, other: &Entry) -> Ordering {
        let (da, ta, sa) = self.key();
        let (db, tb, sb) = other.key();
        da.total_cmp(&db).then(ta.cmp(&tb)).then(sa.cmp(&sb))
    }
}

/// The priced admission queue of one [`crate::engine::Session`].
#[derive(Debug, Clone)]
pub struct Scheduler {
    /// Kept sorted ascending by [`Entry::key`] (EDF → tier → arrival).
    queue: Vec<Entry>,
    next_seq: u64,
    /// Sum of `total_macs()` over every queued entry — the backlog the
    /// daemon's `Retry-After` drain estimate is computed from.
    queued_macs: u128,
    interactive: Bucket,
    batch: Bucket,
}

impl Scheduler {
    /// `interactive_refill` / `batch_refill` are MACs credited to each
    /// tier's bucket per scheduling round; 0 = unlimited (the default
    /// config — exact FIFO).
    pub fn new(interactive_refill: u128, batch_refill: u128) -> Scheduler {
        Scheduler {
            queue: Vec::new(),
            next_seq: 0,
            queued_macs: 0,
            interactive: Bucket::new(interactive_refill),
            batch: Bucket::new(batch_refill),
        }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Declared-MAC backlog of the queue (prefill + worst-case decode of
    /// every waiting request).
    pub fn queued_macs(&self) -> u128 {
        self.queued_macs
    }

    /// Enqueue a priced request at its deterministic position, returning
    /// the arrival sequence number it was stamped with (the flight
    /// recorder's arrival denomination).
    pub fn push(&mut self, req: InferenceRequest, cost: RequestCost) -> u64 {
        let entry = Entry { seq: self.next_seq, cost, req };
        self.next_seq += 1;
        self.queued_macs += cost.total_macs();
        // stable: equal keys cannot occur (seq is unique), so this is a
        // plain ordered insert
        let pos = self.queue.partition_point(|e| e.cmp_key(&entry) == Ordering::Less);
        let seq = entry.seq;
        self.queue.insert(pos, entry);
        seq
    }

    /// Start a scheduling round: refill both tier buckets.
    pub fn begin_round(&mut self) {
        self.interactive.begin_round();
        self.batch.begin_round();
    }

    /// Pop the best admissible request — the first entry in key order
    /// whose tier bucket has credit — charging its declared cost to the
    /// bucket. `None` when the queue is empty or every queued tier is out
    /// of credit this round.
    pub fn pop_admissible(&mut self) -> Option<(InferenceRequest, RequestCost)> {
        let pos = self.queue.iter().position(|e| self.bucket(e.req.tier).admissible())?;
        let entry = self.queue.remove(pos);
        self.queued_macs -= entry.cost.total_macs();
        match entry.req.tier {
            Tier::Interactive => self.interactive.charge(entry.cost.total_macs()),
            Tier::Batch => self.batch.charge(entry.cost.total_macs()),
        }
        Some((entry.req, entry.cost))
    }

    /// Pop the best entry regardless of bucket credit (still charging its
    /// tier) — the work-conserving escape hatch: an otherwise idle engine
    /// never waits on a dry bucket, so metering can delay work but never
    /// deadlock it.
    pub fn pop_front_forced(&mut self) -> Option<(InferenceRequest, RequestCost)> {
        if self.queue.is_empty() {
            return None;
        }
        let entry = self.queue.remove(0);
        self.queued_macs -= entry.cost.total_macs();
        match entry.req.tier {
            Tier::Interactive => self.interactive.charge(entry.cost.total_macs()),
            Tier::Batch => self.batch.charge(entry.cost.total_macs()),
        }
        Some((entry.req, entry.cost))
    }

    /// Remove a queued request by id (cancellation), handing it back.
    pub fn remove(&mut self, id: usize) -> Option<InferenceRequest> {
        let pos = self.queue.iter().position(|e| e.req.id == id)?;
        let entry = self.queue.remove(pos);
        self.queued_macs -= entry.cost.total_macs();
        Some(entry.req)
    }

    /// Queued interactive requests that could be admitted this round
    /// (0 while the interactive bucket is in deficit) — the preemption
    /// trigger's demand side.
    pub fn admissible_interactive(&self) -> usize {
        if !self.interactive.admissible() {
            return 0;
        }
        self.queue.iter().filter(|e| e.req.tier == Tier::Interactive).count()
    }

    /// Whether the batch tier has spent past its budget (credit < 0) —
    /// the preemption trigger's supply side. Always false for an
    /// unlimited bucket, so preemption cannot fire in the default config.
    pub fn batch_over_budget(&self) -> bool {
        self.batch.over_budget()
    }

    /// Remaining bucket credit for `tier` — the flight recorder's
    /// `bucket_credit` field. An unlimited bucket reports 0 (it has no
    /// meaningful balance), keeping the value deterministic across
    /// configs.
    pub fn tier_credit(&self, tier: Tier) -> i128 {
        let b = self.bucket(tier);
        if b.refill == 0 {
            0
        } else {
            b.credit
        }
    }

    /// Id and tier of the front-of-queue entry (the one a dry-bucket
    /// deferral is holding back), without popping it.
    pub fn peek_front(&self) -> Option<(usize, Tier)> {
        self.queue.first().map(|e| (e.req.id, e.req.tier))
    }

    /// Id of the first queued interactive request that could be admitted
    /// this round — the beneficiary a preemption is making room for.
    /// `None` while the interactive bucket is in deficit or no
    /// interactive request is queued.
    pub fn first_admissible_interactive(&self) -> Option<usize> {
        if !self.interactive.admissible() {
            return None;
        }
        self.queue.iter().find(|e| e.req.tier == Tier::Interactive).map(|e| e.req.id)
    }

    fn bucket(&self, tier: Tier) -> &Bucket {
        match tier {
            Tier::Interactive => &self.interactive,
            Tier::Batch => &self.batch,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(macs: u128) -> RequestCost {
        RequestCost { prefill_macs: macs, decode_macs: 0, kv_bytes: 0 }
    }

    fn gen(id: usize) -> InferenceRequest {
        InferenceRequest::generate(id, vec![1, 2], None)
    }

    #[test]
    fn single_tier_no_deadlines_is_exact_fifo() {
        let mut s = Scheduler::new(0, 0);
        for id in 0..16 {
            s.push(gen(id), cost(100 + id as u128));
        }
        s.begin_round();
        for want in 0..16 {
            let (req, _) = s.pop_admissible().expect("unlimited bucket admits all");
            assert_eq!(req.id, want, "default config must reduce to FIFO");
        }
        assert!(s.is_empty());
        assert_eq!(s.queued_macs(), 0);
    }

    #[test]
    fn ordering_is_deadline_then_tier_then_arrival() {
        let mut s = Scheduler::new(0, 0);
        s.push(gen(0), cost(1)); // batch, no deadline
        s.push(gen(1).with_deadline(5.0), cost(1));
        s.push(gen(2).with_tier(Tier::Interactive), cost(1)); // no deadline
        s.push(gen(3).with_deadline(2.0), cost(1));
        s.push(gen(4).with_deadline(5.0).with_tier(Tier::Interactive), cost(1));
        s.begin_round();
        let order: Vec<usize> = std::iter::from_fn(|| s.pop_admissible())
            .map(|(r, _)| r.id)
            .collect();
        // deadline 2.0 first; at deadline 5.0 interactive (4) outranks
        // batch (1); the deadline-less pair sorts at +inf where tier
        // ranks interactive (2) before batch (0)
        assert_eq!(order, [3, 4, 1, 2, 0]);
    }

    #[test]
    fn buckets_meter_and_carry_deficit() {
        // batch budget 100/round; interactive unlimited
        let mut s = Scheduler::new(0, 100);
        s.push(gen(0), cost(250)); // batch, over one round's budget
        s.push(gen(1), cost(10));
        s.push(gen(2).with_tier(Tier::Interactive), cost(1000));
        s.begin_round();
        // interactive is unmetered; batch admits 0 first (EDF arrival
        // order among the admissible) and goes into deficit
        let (a, _) = s.pop_admissible().unwrap();
        assert_eq!(a.id, 2, "interactive sorts ahead at equal (none) deadline");
        let (b, _) = s.pop_admissible().unwrap();
        assert_eq!(b.id, 0);
        assert!(s.batch_over_budget(), "250 against a 100 budget is a deficit");
        assert!(s.pop_admissible().is_none(), "batch throttled, id 1 must wait");
        assert_eq!(s.len(), 1);
        // deficit -150; +100 → -50: still throttled
        s.begin_round();
        assert!(s.pop_admissible().is_none());
        // -50 + 100 → 50: credit again
        s.begin_round();
        assert!(!s.batch_over_budget());
        let (c, _) = s.pop_admissible().unwrap();
        assert_eq!(c.id, 1, "deficit repaid after two refills");
    }

    #[test]
    fn remove_and_backlog_accounting() {
        let mut s = Scheduler::new(0, 0);
        s.push(gen(0), cost(40));
        s.push(gen(1), cost(2));
        assert_eq!(s.queued_macs(), 42);
        assert!(s.remove(7).is_none());
        let r = s.remove(0).expect("queued id is removable");
        assert_eq!(r.id, 0);
        assert_eq!(s.queued_macs(), 2);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn observability_accessors_are_deterministic() {
        let mut s = Scheduler::new(50, 100);
        assert_eq!(s.push(gen(0), cost(30)), 0, "arrival seq starts at 0");
        assert_eq!(s.push(gen(1).with_tier(Tier::Interactive), cost(40)), 1);
        s.begin_round();
        // interactive (no deadline) sorts ahead of batch at the front
        assert_eq!(s.peek_front(), Some((1, Tier::Interactive)));
        assert_eq!(s.first_admissible_interactive(), Some(1));
        assert_eq!(s.tier_credit(Tier::Interactive), 50);
        assert_eq!(s.tier_credit(Tier::Batch), 100);
        let (req, _) = s.pop_admissible().unwrap();
        assert_eq!(req.id, 1);
        assert_eq!(s.tier_credit(Tier::Interactive), 10, "charge is visible");
        // unlimited buckets always report credit 0
        let mut u = Scheduler::new(0, 0);
        u.push(gen(5), cost(1_000_000));
        u.begin_round();
        assert_eq!(u.tier_credit(Tier::Batch), 0);
        assert_eq!(u.peek_front(), Some((5, Tier::Batch)));
        assert_eq!(u.first_admissible_interactive(), None, "no interactive queued");
        assert_eq!(Scheduler::new(0, 0).peek_front(), None);
    }

    #[test]
    fn admissible_interactive_respects_the_bucket() {
        let mut s = Scheduler::new(50, 0);
        s.push(gen(0).with_tier(Tier::Interactive), cost(200));
        s.push(gen(1).with_tier(Tier::Interactive), cost(10));
        s.push(gen(2), cost(1));
        s.begin_round();
        assert_eq!(s.admissible_interactive(), 2);
        let (first, _) = s.pop_admissible().unwrap();
        assert_eq!(first.id, 0);
        // interactive now in deficit: its queued request no longer counts
        assert_eq!(s.admissible_interactive(), 0);
        let (next, _) = s.pop_admissible().unwrap();
        assert_eq!(next.id, 2, "batch keeps flowing while interactive repays");
    }
}
