//! `artifacts/manifest.json` — the contract between the AOT exporter
//! (`python/compile/aot.py`) and the Rust runtime. The marshaller follows
//! these specs positionally and never guesses shapes. Parsed with the
//! in-crate JSON substrate (the build is offline; no serde).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub format_version: usize,
    pub model_config: ModelConfigJson,
    pub tokenizer: TokenizerSpec,
    pub param_names: Vec<String>,
    pub maskable_names: Vec<String>,
    pub capture_names: Vec<String>,
    pub module_budgets: BTreeMap<String, f64>,
    pub entries: BTreeMap<String, EntrySpec>,
}

#[derive(Debug, Clone)]
pub struct ModelConfigJson {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub rope_theta: f64,
    pub norm_eps: f64,
    pub train_batch: usize,
    pub train_seq: usize,
    pub eval_batch: usize,
    pub eval_seq: usize,
    pub adam_beta1: f64,
    pub adam_beta2: f64,
    pub adam_eps: f64,
    pub weight_decay: f64,
}

#[derive(Debug, Clone)]
pub struct TokenizerSpec {
    pub bos: i32,
    pub eos: i32,
    pub pad: i32,
    pub sep: i32,
    pub vocab_used: usize,
}

#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
}

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

fn arg_spec(j: &Json) -> Result<ArgSpec> {
    Ok(ArgSpec {
        name: j.get("name")?.as_str()?.to_string(),
        shape: j.get("shape")?.usize_vec()?,
        dtype: j.get("dtype")?.as_str()?.to_string(),
    })
}

impl Manifest {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Manifest> {
        let path = artifacts_dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text).with_context(|| format!("parse {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let format_version = j.get("format_version")?.as_usize()?;
        if format_version != 1 {
            bail!("unsupported manifest format_version {format_version}");
        }

        let mc = j.get("model_config")?;
        let model_config = ModelConfigJson {
            vocab: mc.get("vocab")?.as_usize()?,
            d_model: mc.get("d_model")?.as_usize()?,
            n_heads: mc.get("n_heads")?.as_usize()?,
            n_layers: mc.get("n_layers")?.as_usize()?,
            d_ff: mc.get("d_ff")?.as_usize()?,
            rope_theta: mc.get("rope_theta")?.as_f64()?,
            norm_eps: mc.get("norm_eps")?.as_f64()?,
            train_batch: mc.get("train_batch")?.as_usize()?,
            train_seq: mc.get("train_seq")?.as_usize()?,
            eval_batch: mc.get("eval_batch")?.as_usize()?,
            eval_seq: mc.get("eval_seq")?.as_usize()?,
            adam_beta1: mc.get("adam_beta1")?.as_f64()?,
            adam_beta2: mc.get("adam_beta2")?.as_f64()?,
            adam_eps: mc.get("adam_eps")?.as_f64()?,
            weight_decay: mc.get("weight_decay")?.as_f64()?,
        };

        let tk = j.get("tokenizer")?;
        let tokenizer = TokenizerSpec {
            bos: tk.get("bos")?.as_i32()?,
            eos: tk.get("eos")?.as_i32()?,
            pad: tk.get("pad")?.as_i32()?,
            sep: tk.get("sep")?.as_i32()?,
            vocab_used: tk.get("vocab_used")?.as_usize()?,
        };

        let module_budgets = j
            .get("module_budgets")?
            .as_obj()?
            .iter()
            .map(|(k, v)| Ok((k.clone(), v.as_f64()?)))
            .collect::<Result<BTreeMap<_, _>>>()?;

        let mut entries = BTreeMap::new();
        for (name, e) in j.get("entries")?.as_obj()? {
            let args = e.get("args")?.as_arr()?.iter().map(arg_spec).collect::<Result<Vec<_>>>()?;
            let outputs =
                e.get("outputs")?.as_arr()?.iter().map(arg_spec).collect::<Result<Vec<_>>>()?;
            entries.insert(
                name.clone(),
                EntrySpec { file: e.get("file")?.as_str()?.to_string(), args, outputs },
            );
        }

        Ok(Manifest {
            format_version,
            model_config,
            tokenizer,
            param_names: j.get("param_names")?.str_vec()?,
            maskable_names: j.get("maskable_names")?.str_vec()?,
            capture_names: j.get("capture_names")?.str_vec()?,
            module_budgets,
            entries,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries.get(name).with_context(|| {
            format!("entry `{name}` not in manifest (have: {:?})", self.entries.keys().collect::<Vec<_>>())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<std::path::PathBuf> {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn loads_real_manifest() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: artifacts missing");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.format_version, 1);
        assert_eq!(m.param_names.len(), 2 + 9 * m.model_config.n_layers);
        assert_eq!(m.maskable_names.len(), 7 * m.model_config.n_layers);
        for e in ["forward_logits", "score_fwd", "train_step", "block_capture", "covariance_d"] {
            assert!(m.entries.contains_key(e), "{e}");
        }
        let ts = m.entry("train_step").unwrap();
        assert_eq!(ts.args.len(), 3 * m.param_names.len() + 4);
        assert_eq!(ts.outputs.len(), 3 * m.param_names.len() + 1);
        assert_eq!(m.tokenizer.pad, 258);
        assert!((m.module_budgets["b46"] - 0.46).abs() < 1e-12);
    }

    #[test]
    fn missing_entry_is_error() {
        let Some(dir) = artifacts() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.entry("nonexistent").is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let text = r#"{"format_version": 9}"#;
        assert!(Manifest::parse(text).is_err());
    }
}
