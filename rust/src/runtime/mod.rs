//! PJRT runtime layer: manifest-driven loading and execution of the AOT
//! artifacts produced by `python/compile/aot.py`.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`, plus a
//! compiled-executable cache and positional tensor marshalling.

pub mod client;
pub mod manifest;

pub use client::{literal_to_tensor, tensor_to_literal, Runtime};
pub use manifest::{ArgSpec, EntrySpec, Manifest, ModelConfigJson, TokenizerSpec};
