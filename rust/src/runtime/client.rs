//! PJRT runtime: load HLO-text artifacts, compile once, execute many.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin) behind a [`Runtime`] that
//! marshals [`Tensor`]s to/from XLA literals according to the manifest's
//! positional specs. Executables are compiled lazily and cached, so the
//! coordinator can call entries by name from the hot path. HLO *text* is
//! the interchange format (jax ≥ 0.5 protos are rejected by xla_extension
//! 0.5.1 — see /opt/xla-example/README.md).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::tensor::{DType, Tensor};

use super::manifest::{ArgSpec, EntrySpec, Manifest};

/// Compiled-executable cache + marshalling layer over one PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// Cumulative executions per entry (coordinator metrics).
    calls: RefCell<HashMap<String, u64>>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest.
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Runtime> {
        let artifacts_dir = artifacts_dir.into();
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime {
            client,
            artifacts_dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            calls: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Ensure `entry` is compiled (idempotent); returns compile time in
    /// seconds when a compile actually happened.
    pub fn warmup(&self, entry: &str) -> Result<Option<f64>> {
        if self.cache.borrow().contains_key(entry) {
            return Ok(None);
        }
        let spec = self.manifest.entry(entry)?.clone();
        let t0 = std::time::Instant::now();
        let exe = self.compile_entry(entry, &spec)?;
        let dt = t0.elapsed().as_secs_f64();
        self.cache.borrow_mut().insert(entry.to_string(), exe);
        Ok(Some(dt))
    }

    fn compile_entry(&self, entry: &str, spec: &EntrySpec) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.artifacts_dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("XLA compile of entry `{entry}`"))
    }

    /// Execute an entry by name with positional tensor arguments.
    ///
    /// Shapes/dtypes are validated against the manifest before the call;
    /// outputs are validated after. The single tuple result (jax lowers
    /// with `return_tuple=True`) is decomposed into per-output tensors.
    pub fn execute(&self, entry: &str, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let spec = self.manifest.entry(entry)?.clone();
        if args.len() != spec.args.len() {
            bail!("entry `{entry}`: {} args given, {} expected", args.len(), spec.args.len());
        }
        for (t, a) in args.iter().zip(&spec.args) {
            validate(entry, t, a)?;
        }
        self.warmup(entry)?;

        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|t| tensor_to_literal(t))
            .collect::<Result<_>>()?;

        let cache = self.cache.borrow();
        let exe = cache.get(entry).expect("warmed up above");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("execute `{entry}`"))?;
        *self.calls.borrow_mut().entry(entry.to_string()).or_insert(0) += 1;

        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetch result of `{entry}`"))?;
        let parts = tuple.to_tuple().context("decompose result tuple")?;
        if parts.len() != spec.outputs.len() {
            bail!("entry `{entry}`: {} outputs, {} expected", parts.len(), spec.outputs.len());
        }
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, o)| {
                let t = literal_to_tensor(&lit)?;
                validate(entry, &&t, o)?;
                Ok(t)
            })
            .collect()
    }

    /// Pre-convert tensors to XLA literals (host copy happens once).
    ///
    /// The eval hot path calls `score_fwd` dozens of times with the same
    /// 74 parameter tensors; converting them per call costs a full
    /// params-sized memcpy + allocation each time. Prepare once, then
    /// [`Runtime::execute_prepared`] with per-batch literals appended.
    pub fn prepare(&self, args: &[&Tensor]) -> Result<Vec<xla::Literal>> {
        args.iter().map(|t| tensor_to_literal(t)).collect()
    }

    /// Execute with pre-converted leading literals plus trailing tensor
    /// args (converted here). Validation matches [`Runtime::execute`].
    pub fn execute_prepared(
        &self,
        entry: &str,
        prepared: &[xla::Literal],
        tail: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        let spec = self.manifest.entry(entry)?.clone();
        if prepared.len() + tail.len() != spec.args.len() {
            bail!(
                "entry `{entry}`: {}+{} args given, {} expected",
                prepared.len(),
                tail.len(),
                spec.args.len()
            );
        }
        for (t, a) in tail.iter().zip(&spec.args[prepared.len()..]) {
            validate(entry, t, a)?;
        }
        self.warmup(entry)?;
        let mut literals: Vec<xla::Literal> = Vec::with_capacity(spec.args.len());
        // XLA literals are opaque handles; cloning copies the buffer, so
        // borrow via a small shim: execute takes Borrow<Literal>.
        let tail_lits: Vec<xla::Literal> = tail.iter().map(|t| tensor_to_literal(t)).collect::<Result<_>>()?;
        let all: Vec<&xla::Literal> = prepared.iter().chain(tail_lits.iter()).collect();
        let _ = &mut literals;

        let cache = self.cache.borrow();
        let exe = cache.get(entry).expect("warmed up above");
        let result = exe
            .execute::<&xla::Literal>(&all)
            .with_context(|| format!("execute `{entry}`"))?;
        *self.calls.borrow_mut().entry(entry.to_string()).or_insert(0) += 1;

        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetch result of `{entry}`"))?;
        let parts = tuple.to_tuple().context("decompose result tuple")?;
        if parts.len() != spec.outputs.len() {
            bail!("entry `{entry}`: {} outputs, {} expected", parts.len(), spec.outputs.len());
        }
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, o)| {
                let t = literal_to_tensor(&lit)?;
                validate(entry, &&t, o)?;
                Ok(t)
            })
            .collect()
    }

    /// Per-entry call counts (metrics surface for the coordinator).
    pub fn call_counts(&self) -> HashMap<String, u64> {
        self.calls.borrow().clone()
    }

    /// Number of compiled executables currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.borrow().len()
    }
}

fn validate(entry: &str, t: &&Tensor, spec: &ArgSpec) -> Result<()> {
    let want_dtype = match spec.dtype.as_str() {
        "f32" => DType::F32,
        "i32" => DType::I32,
        other => bail!("entry `{entry}` arg `{}`: unsupported manifest dtype {other}", spec.name),
    };
    if t.dtype() != want_dtype {
        bail!("entry `{entry}` arg `{}`: dtype {:?}, manifest wants {:?}", spec.name, t.dtype(), want_dtype);
    }
    if t.shape() != spec.shape.as_slice() {
        bail!("entry `{entry}` arg `{}`: shape {:?}, manifest wants {:?}", spec.name, t.shape(), spec.shape);
    }
    Ok(())
}

/// Tensor -> XLA literal (host copy).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let (ty, bytes) = match t {
        Tensor::F32 { data, .. } => (xla::ElementType::F32, bytemuck_f32(data)),
        Tensor::I32 { data, .. } => (xla::ElementType::S32, bytemuck_i32(data)),
        _ => bail!("unsupported literal dtype {:?}", t.dtype()),
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, t.shape(), &bytes)
        .map_err(|e| anyhow::anyhow!("create literal: {e:?}"))
}

/// XLA literal -> Tensor.
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            let data = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("literal f32: {e:?}"))?;
            Ok(Tensor::from_f32(&dims, data))
        }
        xla::ElementType::S32 => {
            let data = lit.to_vec::<i32>().map_err(|e| anyhow::anyhow!("literal i32: {e:?}"))?;
            Ok(Tensor::from_i32(&dims, data))
        }
        other => bail!("unsupported literal element type {other:?}"),
    }
}

fn bytemuck_f32(xs: &[f32]) -> Vec<u8> {
    xs.iter().flat_map(|x| x.to_le_bytes()).collect()
}

fn bytemuck_i32(xs: &[i32]) -> Vec<u8> {
    xs.iter().flat_map(|x| x.to_le_bytes()).collect()
}
