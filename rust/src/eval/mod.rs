//! Zero-shot evaluation: LLaMA-protocol multiple-choice scoring +
//! perplexity, over the AOT `score_fwd` graph.
//!
//! Each (instance, choice) pair is scored by the mean per-token logprob of
//! the choice span (length normalization, as in the paper's harness); the
//! argmax choice is the prediction. Results aggregate per task into the
//! paper's Table 1-4 rows.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::data::{encode_mc_batches, McInstance, Split, Task, TaskKind, World, ALL_TASKS};
use crate::model::{ModelConfig, ParamStore};
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// Accuracy of one task.
#[derive(Debug, Clone, Copy)]
pub struct TaskScore {
    pub kind: TaskKind,
    pub correct: usize,
    pub total: usize,
}

impl TaskScore {
    pub fn accuracy(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.correct as f64 / self.total as f64
        }
    }
}

/// Full evaluation report (one table row).
#[derive(Debug, Clone)]
pub struct EvalReport {
    pub tasks: Vec<TaskScore>,
    pub perplexity: Option<f64>,
}

impl EvalReport {
    pub fn average(&self) -> f64 {
        if self.tasks.is_empty() {
            return 0.0;
        }
        self.tasks.iter().map(|t| t.accuracy()).sum::<f64>() / self.tasks.len() as f64
    }

    pub fn accuracy_of(&self, kind: TaskKind) -> Option<f64> {
        self.tasks.iter().find(|t| t.kind == kind).map(|t| t.accuracy())
    }

    /// One formatted row: per-task % then average %.
    pub fn row(&self) -> String {
        let mut cells: Vec<String> =
            self.tasks.iter().map(|t| format!("{:5.1}", 100.0 * t.accuracy())).collect();
        cells.push(format!("{:5.1}", 100.0 * self.average()));
        cells.join("  ")
    }
}

/// Evaluator bound to one runtime.
pub struct Evaluator<'rt> {
    runtime: &'rt Runtime,
    cfg: ModelConfig,
}

impl<'rt> Evaluator<'rt> {
    pub fn new(runtime: &'rt Runtime) -> Evaluator<'rt> {
        let cfg = ModelConfig::from_manifest(&runtime.manifest().model_config);
        Evaluator { runtime, cfg }
    }

    /// Mean per-token logprob of each (instance, choice): the LLaMA
    /// length-normalized score.
    pub fn score_instances(
        &self,
        params: &ParamStore,
        instances: &[McInstance],
    ) -> Result<Vec<Vec<f64>>> {
        let (eb, es) = (self.cfg.eval_batch, self.cfg.eval_seq);
        let batches = encode_mc_batches(instances, eb, es)?;
        let mut scores: Vec<Vec<f64>> =
            instances.iter().map(|i| vec![f64::NEG_INFINITY; i.choices.len()]).collect();
        // marshal the (unchanging) parameters into XLA literals once —
        // §Perf: saves a params-sized copy per batch on the eval hot path
        let prepared = self.runtime.prepare(&params.flat())?;
        for mb in &batches {
            let tokens = Tensor::from_i32(&[eb, es], mb.tokens.clone());
            let targets = Tensor::from_i32(&[eb, es], mb.targets.clone());
            let mask = Tensor::from_f32(&[eb, es], mb.mask.clone());
            let outs = self
                .runtime
                .execute_prepared("score_fwd", &prepared, &[&tokens, &targets, &mask])
                .context("score_fwd")?;
            let sums = outs[0].as_f32()?;
            let counts = outs[1].as_f32()?;
            for (r, row) in mb.rows.iter().enumerate() {
                let c = counts[r].max(1.0) as f64;
                scores[row.instance][row.choice] = sums[r] as f64 / c;
            }
        }
        Ok(scores)
    }

    /// Accuracy over a set of instances of one task.
    pub fn eval_task(&self, params: &ParamStore, instances: &[McInstance]) -> Result<TaskScore> {
        let scores = self.score_instances(params, instances)?;
        let mut correct = 0;
        for (inst, s) in instances.iter().zip(&scores) {
            let pred = s
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            if pred == inst.gold {
                correct += 1;
            }
        }
        Ok(TaskScore { kind: instances[0].task, correct, total: instances.len() })
    }

    /// Evaluate all six tasks on the eval split (`n_per_task` instances
    /// each) and optionally corpus perplexity.
    pub fn eval_suite(
        &self,
        params: &ParamStore,
        world: &World,
        n_per_task: usize,
        seed: u64,
        ppl_text: Option<&str>,
    ) -> Result<EvalReport> {
        let mut tasks = Vec::new();
        for kind in ALL_TASKS {
            let gen = Task::new(world, kind);
            let instances = gen.generate(Split::Eval, n_per_task, seed);
            tasks.push(self.eval_task(params, &instances)?);
        }
        let perplexity = match ppl_text {
            Some(text) => Some(self.perplexity(params, text)?),
            None => None,
        };
        Ok(EvalReport { tasks, perplexity })
    }

    /// Corpus perplexity via the same scoring graph (mask = all non-PAD
    /// target positions).
    pub fn perplexity(&self, params: &ParamStore, text: &str) -> Result<f64> {
        let (eb, es) = (self.cfg.eval_batch, self.cfg.eval_seq);
        let tk = crate::data::Tokenizer::new();
        let ids = tk.encode(text);
        let window = es; // BOS + window-1 bytes, target shifts
        let mut total_lp = 0.0f64;
        let mut total_tokens = 0.0f64;
        let n_rows = (ids.len() - 1) / (window - 1);
        let rows = n_rows.min(4 * eb); // bounded work
        let mut row_tokens: Vec<i32> = Vec::new();
        let mut row_targets: Vec<i32> = Vec::new();
        let mut row_mask: Vec<f32> = Vec::new();
        let mut rows_in_batch = 0;
        let flush = |tokens: &mut Vec<i32>,
                         targets: &mut Vec<i32>,
                         mask: &mut Vec<f32>,
                         rows_in_batch: &mut usize|
         -> Result<(f64, f64)> {
            if *rows_in_batch == 0 {
                return Ok((0.0, 0.0));
            }
            while *rows_in_batch < eb {
                tokens.extend(std::iter::repeat(crate::data::PAD).take(es));
                targets.extend(std::iter::repeat(crate::data::PAD).take(es));
                mask.extend(std::iter::repeat(0.0f32).take(es));
                *rows_in_batch += 1;
            }
            let t = Tensor::from_i32(&[eb, es], std::mem::take(tokens));
            let g = Tensor::from_i32(&[eb, es], std::mem::take(targets));
            let m = Tensor::from_f32(&[eb, es], std::mem::take(mask));
            let mut args: Vec<&Tensor> = params.flat();
            args.push(&t);
            args.push(&g);
            args.push(&m);
            let outs = self.runtime.execute("score_fwd", &args)?;
            let s: f64 = outs[0].as_f32()?.iter().map(|&x| x as f64).sum();
            let c: f64 = outs[1].as_f32()?.iter().map(|&x| x as f64).sum();
            *rows_in_batch = 0;
            Ok((s, c))
        };

        for r in 0..rows {
            let start = r * (window - 1);
            let span = &ids[start..(start + window).min(ids.len())];
            // tokens = BOS ++ span[..-1]; targets = span
            row_tokens.push(crate::data::BOS);
            row_tokens.extend(&span[..span.len() - 1]);
            row_targets.extend(span);
            row_mask.extend(std::iter::repeat(1.0f32).take(span.len()));
            for _ in span.len()..es {
                row_tokens.push(crate::data::PAD);
                row_targets.push(crate::data::PAD);
                row_mask.push(0.0);
            }
            rows_in_batch += 1;
            if rows_in_batch == eb {
                let (s, c) = flush(&mut row_tokens, &mut row_targets, &mut row_mask, &mut rows_in_batch)?;
                total_lp += s;
                total_tokens += c;
            }
        }
        let (s, c) = flush(&mut row_tokens, &mut row_targets, &mut row_mask, &mut rows_in_batch)?;
        total_lp += s;
        total_tokens += c;
        if total_tokens == 0.0 {
            anyhow::bail!("perplexity: no tokens scored");
        }
        Ok((-total_lp / total_tokens).exp())
    }
}

/// Pretty-print a set of labeled reports as the paper's table layout.
pub fn format_table(title: &str, rows: &[(String, EvalReport)]) -> String {
    let mut out = String::new();
    out.push_str(&format!("\n## {title}\n"));
    let header: Vec<&str> = ALL_TASKS.iter().map(|k| k.paper_name()).collect();
    out.push_str(&format!("{:<28} {}  Avg\n", "Variant", header.join("  ")));
    for (label, rep) in rows {
        out.push_str(&format!("{label:<28} {}", rep.row()));
        if let Some(ppl) = rep.perplexity {
            out.push_str(&format!("   (ppl {ppl:.2})"));
        }
        out.push('\n');
    }
    out
}

/// Per-task accuracy map (test convenience).
pub fn accuracy_map(rep: &EvalReport) -> BTreeMap<&'static str, f64> {
    rep.tasks.iter().map(|t| (t.kind.name(), t.accuracy())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_average() {
        let rep = EvalReport {
            tasks: vec![
                TaskScore { kind: TaskKind::BoolLike, correct: 80, total: 100 },
                TaskScore { kind: TaskKind::QaEasy, correct: 40, total: 100 },
            ],
            perplexity: None,
        };
        assert!((rep.average() - 0.6).abs() < 1e-12);
        assert_eq!(rep.accuracy_of(TaskKind::BoolLike), Some(0.8));
        assert_eq!(rep.accuracy_of(TaskKind::QaHard), None);
    }

    #[test]
    fn format_table_contains_labels() {
        let rep = EvalReport {
            tasks: vec![TaskScore { kind: TaskKind::BoolLike, correct: 1, total: 2 }],
            perplexity: Some(3.5),
        };
        let s = format_table("Table X", &[("dense".into(), rep)]);
        assert!(s.contains("Table X"));
        assert!(s.contains("dense"));
        assert!(s.contains("50.0"));
        assert!(s.contains("ppl 3.50"));
    }
}
