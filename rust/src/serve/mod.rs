//! Factored-form serving engine — execute the paper's re-parameterization
//! instead of just accounting for it.
//!
//! The central claim of the re-parameterization `W ≈ W1·W2` (`W1 = V_rᵀ`,
//! `W2 = V_r W`) is that inference cost drops from `d1·d2` to `r(d1+d2)`
//! MACs per token. Everywhere else in this crate the compressed model runs
//! *re-densified* (`W_eff = W1·W2` through the unmodified dense graphs);
//! this module is the serving path that runs the factors directly:
//!
//! - [`ServeLayer`] — per-matrix dense/low-rank/quantized dispatch: a
//!   compressed layer applies as two skinny matmuls `y = (x·W2ᵀ)·W1ᵀ`, a
//!   dense layer as one, both over cache-aware packed panels on the
//!   fixed-lane-order SIMD kernels ([`crate::linalg::simd`]); under
//!   [`ExecMode::FactoredQuant`] the factors execute as per-row int8
//!   codes with f32 accumulation (same MACs, ~4× fewer weight bytes,
//!   logits within a stated tolerance of the f32 factored path — and
//!   only when selected explicitly).
//! - [`ServeModel`] — a full MiniLLaMA forward built from a
//!   [`CompressedModel`] artifact (factors restored from the `.rtz`
//!   sidecars), counting the MACs it actually executes, with a shared
//!   rope table and a per-request scratch arena ([`model::ServeScratch`])
//!   so steady-state decode does no hot-path allocation.
//! - [`ServeEngine`] — the batch serving front-end, now a thin adapter
//!   over the shared streaming core ([`crate::engine`]): requests flow
//!   through the core's bounded queue and parallel lanes, with
//!   latency/throughput/MAC accounting ([`ServeStats`], embedding the
//!   shared [`crate::util::RequestStats`] core) that confirms the
//!   `r(d1+d2)` vs `d1·d2` speedup empirically (`repro bench-serve`).
//!
//! The demo helpers at the bottom ([`demo_artifact`], [`synth_requests`])
//! make the whole path self-contained: they synthesize a small compressed
//! artifact offline (data-free weight-space ROM), which is what
//! `repro serve --self-check` and `scripts/verify.sh` smoke-test.

pub mod engine;
pub mod layer;
pub mod model;

use anyhow::{bail, Result};

use crate::compress::{CompressedModel, CompressionSession, EmptyStream};
use crate::model::{param_shape, ModelConfig, ParamStore};
use crate::tensor::Tensor;
use crate::util::Rng;

pub use engine::{ServeConfig, ServeEngine, ServeRequest, ServeResult, ServeStats};
pub use layer::ServeLayer;
pub use model::{ServeModel, ServeScratch};

/// Which form compressed layers execute in. Always chosen explicitly
/// (CLI `--mode`, daemon startup flag) — in particular the quantized
/// mode is never a silent substitute for the f32 factored path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Re-densified `W_eff = W1·W2`: one `d2×d1` matmul per layer — the
    /// baseline every other consumer of the artifact runs.
    Dense,
    /// The paper's factored form: two skinny matmuls, `r(d1+d2)` MACs.
    Factored,
    /// The factored form over per-row symmetric int8 factors with f32
    /// accumulation: same `r(d1+d2)` MACs, ~4× fewer weight bytes,
    /// logits within a stated tolerance of [`ExecMode::Factored`]
    /// (asserted by `repro serve --self-check --mode factored-quant`).
    FactoredQuant,
}

impl ExecMode {
    pub fn parse(s: &str) -> Result<ExecMode> {
        Ok(match s {
            "dense" => ExecMode::Dense,
            "factored" => ExecMode::Factored,
            "factored-quant" => ExecMode::FactoredQuant,
            other => bail!("unknown serve mode `{other}` (dense|factored|factored-quant)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Dense => "dense",
            ExecMode::Factored => "factored",
            ExecMode::FactoredQuant => "factored-quant",
        }
    }

    /// The storage form this mode implies for the analytic byte
    /// accounting in [`crate::model::macs::weight_bytes`].
    pub fn weight_store(self) -> crate::model::macs::WeightStore {
        match self {
            ExecMode::Dense => crate::model::macs::WeightStore::Dense,
            ExecMode::Factored => crate::model::macs::WeightStore::Factored,
            ExecMode::FactoredQuant => crate::model::macs::WeightStore::FactoredQuant,
        }
    }
}

/// Small config for the self-contained serve smoke tests: big enough that
/// the low-rank MAC win is visible, small enough to forward in
/// milliseconds without AOT artifacts.
pub fn demo_config() -> ModelConfig {
    ModelConfig { vocab: 64, d_model: 32, n_heads: 4, n_layers: 3, d_ff: 48, ..ModelConfig::mini() }
}

/// Seeded random parameters (serving demos/tests need no training; norm
/// gains are 1 so activations stay well-scaled).
pub fn random_params(cfg: &ModelConfig, seed: u64) -> Result<ParamStore> {
    let mut p = ParamStore::zeros(cfg);
    let mut rng = Rng::new(seed);
    for name in p.names().to_vec() {
        let shape = param_shape(cfg, &name);
        let n: usize = shape.iter().product();
        let data: Vec<f32> = if shape.len() == 1 {
            vec![1.0; n]
        } else {
            (0..n).map(|_| rng.normal() as f32 * 0.08).collect()
        };
        p.set(&name, Tensor::from_f32(&shape, data))?;
    }
    Ok(p)
}

/// Build a self-contained compressed artifact offline: random params,
/// data-free weight-space ROM at `budget`. Substrate of
/// `repro serve --self-check`, the `repro bench-serve` fallback when no
/// `--ckpt` is given, and `examples/factored_serving.rs`.
pub fn demo_artifact(cfg: &ModelConfig, budget: f64, seed: u64) -> Result<CompressedModel> {
    let params = random_params(cfg, seed)?;
    let session = CompressionSession::offline(cfg.clone());
    let mut calib = EmptyStream;
    session.compress_at("rom-weight-svd", &params, budget, &mut calib)
}

/// Deterministic synthetic workload: `n` requests of `seq` random tokens —
/// a [`ServeRequest`] view over the one shared stream generator
/// [`crate::engine::synth_token_streams`].
pub fn synth_requests(cfg: &ModelConfig, n: usize, seq: usize, seed: u64) -> Vec<ServeRequest> {
    crate::engine::synth_token_streams(cfg, n, seq, seed)
        .into_iter()
        .enumerate()
        .map(|(id, tokens)| ServeRequest { id, tokens })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_mode_parses() {
        assert_eq!(ExecMode::parse("dense").unwrap(), ExecMode::Dense);
        assert_eq!(ExecMode::parse("factored").unwrap(), ExecMode::Factored);
        assert_eq!(ExecMode::parse("factored-quant").unwrap(), ExecMode::FactoredQuant);
        assert!(ExecMode::parse("fast").is_err());
        assert_eq!(ExecMode::Factored.name(), "factored");
        assert_eq!(ExecMode::FactoredQuant.name(), "factored-quant");
    }

    #[test]
    fn demo_artifact_carries_factors() {
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 1).unwrap();
        assert!(!cm.factors.is_empty());
        assert_eq!(cm.factors.len(), cm.accounting.layers.len());
        // budget 1.0 short-circuits to the identity artifact: no factors
        let id = demo_artifact(&cfg, 1.0, 1).unwrap();
        assert!(id.factors.is_empty());
    }

    #[test]
    fn synth_requests_are_deterministic_and_in_vocab() {
        let cfg = demo_config();
        let a = synth_requests(&cfg, 4, 16, 9);
        let b = synth_requests(&cfg, 4, 16, 9);
        assert_eq!(a.len(), 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.tokens.len(), 16);
            assert!(x.tokens.iter().all(|&t| (t as usize) < cfg.vocab));
        }
    }
}
