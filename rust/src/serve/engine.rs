//! Multi-request serving: a shared batching queue drained by the worker
//! pool, with per-request latency and MAC accounting.
//!
//! Requests land in one FIFO; each worker repeatedly claims a batch of up
//! to [`ServeConfig::max_batch`] requests and forwards them through the
//! shared [`ServeModel`] (read-only, so workers need no locking on the
//! weights). The workers are an [`ExecPool`] broadcast, and the engine
//! splits the [`ExecConfig`] thread budget between request-level workers
//! and intra-op row sharding inside each forward — one knob, no
//! oversubscription: `workers` request threads each drive a
//! `threads/workers`-wide matmul pool. Per-request latency is measured
//! from engine start — queue wait plus compute — which is what a caller of
//! a loaded server observes; [`ServeStats`] aggregates latency
//! percentiles, throughput, and the exact MACs executed, the empirical
//! side of the paper's `r(d1+d2)` vs `d1·d2` argument.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::exec::{ExecConfig, ExecPool};
use crate::util::LatencySummary;

use super::model::ServeModel;

/// Engine knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Request-level worker threads (capped by the exec thread budget).
    pub workers: usize,
    /// Max requests a worker claims from the queue per dispatch.
    pub max_batch: usize,
    /// Total thread budget shared by request workers and intra-op row
    /// sharding (the global `--threads` knob; results are invariant to it).
    pub exec: ExecConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { workers: 2, max_batch: 4, exec: ExecConfig::default() }
    }
}

/// One inference request: a token prompt.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: usize,
    pub tokens: Vec<i32>,
}

/// One served response.
#[derive(Debug, Clone)]
pub struct ServeResult {
    pub id: usize,
    /// (seq, vocab) logits for every prompt position.
    pub logits: Vec<f32>,
    /// Prompt length in tokens.
    pub tokens: usize,
    /// MACs executed for this request.
    pub macs: u128,
    /// Queue wait + compute, from engine start to response ready.
    pub latency_s: f64,
}

/// Aggregate accounting for one [`ServeEngine::run`].
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub requests: usize,
    /// Dispatch batches claimed from the queue.
    pub batches: usize,
    pub tokens: usize,
    pub macs: u128,
    /// Wall clock of the whole run (all workers).
    pub wall_s: f64,
    /// Latency summary (small-sample safe: 0 or 1 completed requests
    /// yield well-defined values, not degenerate indexing).
    pub latency: LatencySummary,
}

impl ServeStats {
    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.tokens as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Wall clock amortized per served token.
    pub fn s_per_token(&self) -> f64 {
        if self.tokens > 0 {
            self.wall_s / self.tokens as f64
        } else {
            0.0
        }
    }

    pub fn macs_per_token(&self) -> u128 {
        if self.tokens > 0 {
            self.macs / self.tokens as u128
        } else {
            0
        }
    }
}

/// The batched forward engine over one loaded model.
pub struct ServeEngine {
    model: ServeModel,
    config: ServeConfig,
}

impl ServeEngine {
    pub fn new(model: ServeModel, config: ServeConfig) -> ServeEngine {
        ServeEngine { model, config }
    }

    pub fn model(&self) -> &ServeModel {
        &self.model
    }

    /// Serve every request to completion; results are returned in request
    /// id order along with the run's aggregate stats.
    pub fn run(&self, requests: Vec<ServeRequest>) -> Result<(Vec<ServeResult>, ServeStats)> {
        let n = requests.len();
        let t0 = Instant::now();
        let queue: Mutex<VecDeque<ServeRequest>> = Mutex::new(requests.into());
        let results: Mutex<Vec<ServeResult>> = Mutex::new(Vec::with_capacity(n));
        let batches: Mutex<usize> = Mutex::new(0);
        // once any request fails, other workers stop claiming new batches
        // instead of computing forwards whose results will be discarded
        let failed = AtomicBool::new(false);
        // one thread budget, two levels: `workers` request-claiming pool
        // threads, each driving an intra-op pool over its share — total
        // concurrency never exceeds the exec budget
        let threads = self.config.exec.resolve().max(1);
        let workers = self.config.workers.max(1).min(threads);
        let intra = ExecPool::new(threads).split(workers);
        let pool = ExecPool::new(workers);

        let worker_loop = || -> Result<()> {
            loop {
                if failed.load(Ordering::Relaxed) {
                    break;
                }
                let batch: Vec<ServeRequest> = {
                    let mut q = queue.lock().unwrap();
                    if q.is_empty() {
                        break;
                    }
                    let take = self.config.max_batch.max(1).min(q.len());
                    q.drain(..take).collect()
                };
                *batches.lock().unwrap() += 1;
                for req in batch {
                    let (logits, macs) =
                        match self.model.forward_logits_pooled(&req.tokens, &intra) {
                            Ok(out) => out,
                            Err(e) => {
                                failed.store(true, Ordering::Relaxed);
                                return Err(e);
                            }
                        };
                    let r = ServeResult {
                        id: req.id,
                        tokens: req.tokens.len(),
                        logits,
                        macs,
                        latency_s: t0.elapsed().as_secs_f64(),
                    };
                    results.lock().unwrap().push(r);
                }
            }
            Ok(())
        };
        let outcomes: Vec<Result<()>> = pool.broadcast(|_worker| -> Result<()> {
            // panic containment, matching the engine's pre-pool behavior: a
            // panicking worker surfaces as this run's Err, not a process
            // abort of a long-lived server
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(&worker_loop))
                .unwrap_or_else(|_| {
                    failed.store(true, Ordering::Relaxed);
                    Err(anyhow!("serve worker panicked"))
                })
        });
        for outcome in outcomes {
            outcome?;
        }

        let wall_s = t0.elapsed().as_secs_f64();
        let mut results = results.into_inner().unwrap();
        results.sort_by_key(|r| r.id);
        let stats = ServeStats {
            requests: results.len(),
            batches: batches.into_inner().unwrap(),
            tokens: results.iter().map(|r| r.tokens).sum(),
            macs: results.iter().map(|r| r.macs).sum(),
            wall_s,
            latency: LatencySummary::from_unsorted(
                results.iter().map(|r| r.latency_s).collect(),
            ),
        };
        Ok((results, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{demo_artifact, demo_config, synth_requests, ExecMode};

    fn engine(mode: ExecMode, workers: usize, max_batch: usize) -> ServeEngine {
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 31).unwrap();
        let model = ServeModel::from_artifact(&cm, mode).unwrap();
        // workers beyond the thread budget would be capped — size the
        // budget to the requested workers so the tests exercise them
        let exec = ExecConfig::with_threads(workers.max(1));
        ServeEngine::new(model, ServeConfig { workers, max_batch, exec })
    }

    #[test]
    fn serves_every_request_in_id_order() {
        let e = engine(ExecMode::Factored, 3, 2);
        let reqs = synth_requests(e.model().config(), 9, 12, 7);
        let (results, stats) = e.run(reqs).unwrap();
        assert_eq!(results.len(), 9);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i);
            assert_eq!(r.tokens, 12);
            assert_eq!(r.logits.len(), 12 * e.model().config().vocab);
            assert!(r.macs > 0);
            assert!(r.latency_s >= 0.0 && r.latency_s <= stats.wall_s + 1e-6);
        }
        assert_eq!(stats.requests, 9);
        assert_eq!(stats.tokens, 9 * 12);
        assert_eq!(stats.macs, results.iter().map(|r| r.macs).sum::<u128>());
        // 9 requests at batch 2 need at least 5 dispatches
        assert!(stats.batches >= 5, "batches {}", stats.batches);
        assert!(stats.wall_s > 0.0 && stats.latency.p95 >= stats.latency.mean * 0.5);
    }

    #[test]
    fn worker_parallelism_is_deterministic_on_logits() {
        // same workload through 1 and 4 workers: identical per-request
        // logits (scheduling must not affect results)
        let reqs = |e: &ServeEngine| synth_requests(e.model().config(), 6, 10, 3);
        let e1 = engine(ExecMode::Factored, 1, 1);
        let e4 = engine(ExecMode::Factored, 4, 3);
        let (r1, _) = e1.run(reqs(&e1)).unwrap();
        let (r4, _) = e4.run(reqs(&e4)).unwrap();
        for (a, b) in r1.iter().zip(&r4) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.logits, b.logits);
            assert_eq!(a.macs, b.macs);
        }
    }

    #[test]
    fn thread_budget_is_invisible_in_results() {
        // a fixed worker split under different --threads budgets (serial,
        // balanced, oversubscribed-then-capped): identical logits and MACs
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 37).unwrap();
        let run = |threads: usize| {
            let model = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
            let config = ServeConfig {
                workers: 2,
                max_batch: 2,
                exec: ExecConfig::with_threads(threads),
            };
            let reqs = synth_requests(&cfg, 5, 14, 11);
            ServeEngine::new(model, config).run(reqs).unwrap().0
        };
        let base = run(1);
        for threads in [2usize, 4, 8] {
            let got = run(threads);
            for (a, b) in base.iter().zip(&got) {
                assert_eq!(a.id, b.id, "threads={threads}");
                assert_eq!(a.logits, b.logits, "threads={threads}: logits moved");
                assert_eq!(a.macs, b.macs, "threads={threads}");
            }
        }
    }

    #[test]
    fn empty_and_oversized_batches_are_fine() {
        let e = engine(ExecMode::Dense, 2, 100);
        let (results, stats) = e.run(Vec::new()).unwrap();
        assert!(results.is_empty());
        assert_eq!(stats.requests, 0);
        assert_eq!(stats.macs_per_token(), 0);
        let reqs = synth_requests(e.model().config(), 2, 8, 1);
        let (results, stats) = e.run(reqs).unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(stats.batches, 1, "one worker claims both requests at once");
    }

    #[test]
    fn tiny_sample_counts_have_well_defined_percentiles() {
        // 0 completed requests: every latency figure is zero, not garbage
        let e = engine(ExecMode::Factored, 2, 2);
        let (_, s0) = e.run(Vec::new()).unwrap();
        assert_eq!(s0.latency.n, 0);
        assert_eq!((s0.latency.mean, s0.latency.p95), (0.0, 0.0));
        assert_eq!((s0.latency.p50, s0.latency.max), (0.0, 0.0));
        // 1 completed request: the lone sample is every percentile
        let reqs = synth_requests(e.model().config(), 1, 6, 2);
        let (r1, s1) = e.run(reqs).unwrap();
        assert_eq!(s1.latency.n, 1);
        assert_eq!(s1.latency.mean, r1[0].latency_s);
        assert_eq!(s1.latency.p95, r1[0].latency_s);
        assert_eq!(s1.latency.p50, r1[0].latency_s);
        assert_eq!(s1.latency.max, r1[0].latency_s);
    }

    #[test]
    fn bad_request_surfaces_as_error() {
        let e = engine(ExecMode::Factored, 2, 2);
        let mut reqs = synth_requests(e.model().config(), 3, 8, 1);
        reqs[1].tokens = vec![9999]; // out of vocab
        assert!(e.run(reqs).is_err());
    }
}
