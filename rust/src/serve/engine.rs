//! Multi-request serving front-end — a thin adapter over the shared
//! streaming core ([`crate::engine`]).
//!
//! Requests land in the core's bounded FIFO; each scheduling step claims
//! dispatch batches of up to [`ServeConfig::max_batch`] requests into
//! free lanes and forwards them in parallel through the shared
//! [`ServeModel`] (read-only, so lanes need no locking on the weights).
//! The fan-out runs on the [`crate::exec::ExecPool`], and the engine
//! splits the [`ExecConfig`] thread budget between request-level lanes
//! and intra-op row sharding inside each forward — one knob, no
//! oversubscription. Per-request latency is measured from engine start —
//! queue wait plus compute — which is what a caller of a loaded server
//! observes; [`ServeStats`] embeds the shared
//! [`crate::util::RequestStats`] core (latency percentiles, throughput,
//! and the exact MACs executed, the empirical side of the paper's
//! `r(d1+d2)` vs `d1·d2` argument) plus the dispatch-batch count.

use anyhow::{anyhow, Result};

use crate::engine::{EngineConfig, EngineCore, InferenceRequest};
use crate::exec::ExecConfig;
use crate::util::RequestStats;

use super::model::ServeModel;

/// Engine knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Request-level worker lanes (capped by the exec thread budget).
    pub workers: usize,
    /// Max requests a dispatch batch claims from the queue.
    pub max_batch: usize,
    /// Total thread budget shared by request lanes and intra-op row
    /// sharding (the global `--threads` knob; results are invariant to it).
    pub exec: ExecConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { workers: 2, max_batch: 4, exec: ExecConfig::default() }
    }
}

/// One inference request: a token prompt.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: usize,
    pub tokens: Vec<i32>,
}

/// One served response.
#[derive(Debug, Clone)]
pub struct ServeResult {
    pub id: usize,
    /// (seq, vocab) logits for every prompt position.
    pub logits: Vec<f32>,
    /// Prompt length in tokens.
    pub tokens: usize,
    /// MACs executed for this request.
    pub macs: u128,
    /// Queue wait + compute, from engine start to response ready.
    pub latency_s: f64,
}

/// Aggregate accounting for one [`ServeEngine::run`].
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// The shared request-lifecycle core: requests completed, prompt
    /// tokens scored, MACs executed, wall clock, and the per-request
    /// completion-latency summary (small-sample safe).
    pub core: RequestStats,
    /// Dispatch batches claimed from the queue.
    pub batches: usize,
}

impl ServeStats {
    pub fn tokens_per_s(&self) -> f64 {
        self.core.tokens_per_s()
    }

    /// Wall clock amortized per served token.
    pub fn s_per_token(&self) -> f64 {
        self.core.s_per_token()
    }

    pub fn macs_per_token(&self) -> u128 {
        self.core.macs_per_token()
    }
}

/// The batched forward engine over one loaded model.
pub struct ServeEngine {
    model: ServeModel,
    config: ServeConfig,
}

impl ServeEngine {
    pub fn new(model: ServeModel, config: ServeConfig) -> ServeEngine {
        ServeEngine { model, config }
    }

    pub fn model(&self) -> &ServeModel {
        &self.model
    }

    /// This front-end's knobs as an [`EngineConfig`]: `workers × max_batch`
    /// concurrent lanes, claimed in dispatch batches of `max_batch` and
    /// forwarded at most `workers` at a time (the rest of the thread
    /// budget row-shards inside each forward — the old engine's split).
    fn engine_config(&self, queue_cap: usize) -> EngineConfig {
        let threads = self.config.exec.resolve().max(1);
        let workers = self.config.workers.max(1).min(threads);
        let max_batch = self.config.max_batch.max(1);
        EngineConfig {
            slots: workers * max_batch,
            queue_cap: queue_cap.max(1),
            max_admit: max_batch,
            exec: self.config.exec,
            lane_parallelism: workers,
            ..EngineConfig::default()
        }
    }

    /// Serve every request to completion; results are returned in request
    /// id order along with the run's aggregate stats.
    pub fn run(&self, requests: Vec<ServeRequest>) -> Result<(Vec<ServeResult>, ServeStats)> {
        let ecfg = self.engine_config(requests.len());
        let reqs: Vec<_> = requests.into_iter().map(InferenceRequest::from).collect();
        // fail a bad batch (invalid request, duplicate id) before any
        // compute is spent — the session would reject the offender only
        // after earlier requests already ran
        ecfg.validate_batch(&reqs)?;
        let core = EngineCore::new(&self.model, ecfg);
        // panic containment, the engine's long-standing contract: a
        // panicking forward surfaces as this run's Err, not a process
        // abort of a long-lived server
        let (finished, cs) =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| core.run(reqs)))
                .unwrap_or_else(|_| Err(anyhow!("serve worker panicked")))?;
        let results = finished
            .into_iter()
            .map(|f| ServeResult {
                id: f.id,
                tokens: f.prompt_len,
                logits: f.logits,
                macs: f.macs,
                latency_s: f.latency_s,
            })
            .collect();
        let stats = ServeStats {
            core: RequestStats {
                requests: cs.requests,
                tokens: cs.scored_tokens,
                macs: cs.macs,
                wall_s: cs.wall_s,
                latency: cs.latency,
            },
            batches: cs.batches,
        };
        Ok((results, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{demo_artifact, demo_config, synth_requests, ExecMode};

    fn engine(mode: ExecMode, workers: usize, max_batch: usize) -> ServeEngine {
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 31).unwrap();
        let model = ServeModel::from_artifact(&cm, mode).unwrap();
        // workers beyond the thread budget would be capped — size the
        // budget to the requested workers so the tests exercise them
        let exec = ExecConfig::with_threads(workers.max(1));
        ServeEngine::new(model, ServeConfig { workers, max_batch, exec })
    }

    #[test]
    fn serves_every_request_in_id_order() {
        let e = engine(ExecMode::Factored, 3, 2);
        let reqs = synth_requests(e.model().config(), 9, 12, 7);
        let (results, stats) = e.run(reqs).unwrap();
        assert_eq!(results.len(), 9);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i);
            assert_eq!(r.tokens, 12);
            assert_eq!(r.logits.len(), 12 * e.model().config().vocab);
            assert!(r.macs > 0);
            assert!(r.latency_s >= 0.0 && r.latency_s <= stats.core.wall_s + 1e-6);
        }
        assert_eq!(stats.core.requests, 9);
        assert_eq!(stats.core.tokens, 9 * 12);
        assert_eq!(stats.core.macs, results.iter().map(|r| r.macs).sum::<u128>());
        // 9 requests at batch 2 need at least 5 dispatches
        assert!(stats.batches >= 5, "batches {}", stats.batches);
        assert!(
            stats.core.wall_s > 0.0 && stats.core.latency.p95 >= stats.core.latency.mean * 0.5
        );
    }

    #[test]
    fn worker_parallelism_is_deterministic_on_logits() {
        // same workload through 1 and 4 workers: identical per-request
        // logits (scheduling must not affect results)
        let reqs = |e: &ServeEngine| synth_requests(e.model().config(), 6, 10, 3);
        let e1 = engine(ExecMode::Factored, 1, 1);
        let e4 = engine(ExecMode::Factored, 4, 3);
        let (r1, _) = e1.run(reqs(&e1)).unwrap();
        let (r4, _) = e4.run(reqs(&e4)).unwrap();
        for (a, b) in r1.iter().zip(&r4) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.logits, b.logits);
            assert_eq!(a.macs, b.macs);
        }
    }

    #[test]
    fn thread_budget_is_invisible_in_results() {
        // a fixed worker split under different --threads budgets (serial,
        // balanced, oversubscribed-then-capped): identical logits and MACs
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 37).unwrap();
        let run = |threads: usize| {
            let model = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
            let config = ServeConfig {
                workers: 2,
                max_batch: 2,
                exec: ExecConfig::with_threads(threads),
            };
            let reqs = synth_requests(&cfg, 5, 14, 11);
            ServeEngine::new(model, config).run(reqs).unwrap().0
        };
        let base = run(1);
        for threads in [2usize, 4, 8] {
            let got = run(threads);
            for (a, b) in base.iter().zip(&got) {
                assert_eq!(a.id, b.id, "threads={threads}");
                assert_eq!(a.logits, b.logits, "threads={threads}: logits moved");
                assert_eq!(a.macs, b.macs, "threads={threads}");
            }
        }
    }

    #[test]
    fn empty_and_oversized_batches_are_fine() {
        let e = engine(ExecMode::Dense, 2, 100);
        let (results, stats) = e.run(Vec::new()).unwrap();
        assert!(results.is_empty());
        assert_eq!(stats.core.requests, 0);
        assert_eq!(stats.macs_per_token(), 0);
        let reqs = synth_requests(e.model().config(), 2, 8, 1);
        let (results, stats) = e.run(reqs).unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(stats.batches, 1, "one dispatch batch claims both requests at once");
    }

    #[test]
    fn tiny_sample_counts_have_well_defined_percentiles() {
        // 0 completed requests: every latency figure is zero, not garbage
        let e = engine(ExecMode::Factored, 2, 2);
        let (_, s0) = e.run(Vec::new()).unwrap();
        assert_eq!(s0.core.latency.n, 0);
        assert_eq!((s0.core.latency.mean, s0.core.latency.p95), (0.0, 0.0));
        assert_eq!((s0.core.latency.p50, s0.core.latency.max), (0.0, 0.0));
        // 1 completed request: the lone sample is every percentile
        let reqs = synth_requests(e.model().config(), 1, 6, 2);
        let (r1, s1) = e.run(reqs).unwrap();
        assert_eq!(s1.core.latency.n, 1);
        assert_eq!(s1.core.latency.mean, r1[0].latency_s);
        assert_eq!(s1.core.latency.p95, r1[0].latency_s);
        assert_eq!(s1.core.latency.p50, r1[0].latency_s);
        assert_eq!(s1.core.latency.max, r1[0].latency_s);
    }

    #[test]
    fn bad_request_surfaces_as_error() {
        let e = engine(ExecMode::Factored, 2, 2);
        let mut reqs = synth_requests(e.model().config(), 3, 8, 1);
        reqs[1].tokens = vec![9999]; // out of vocab
        assert!(e.run(reqs).is_err());
    }
}
