//! Batched forward pass over a [`CompressedModel`] artifact with
//! per-layer dense/low-rank/quantized dispatch.
//!
//! Mirrors [`crate::model::ReferenceModel`]'s MiniLLaMA math exactly (same
//! rmsnorm / rope / attention helpers), but every one of the 7
//! decomposable matrices per block goes through a [`ServeLayer`]: factored
//! when the artifact carries [`crate::rom::RomFactors`] for it and the
//! engine runs in [`ExecMode::Factored`] (int8-quantized factors under
//! [`ExecMode::FactoredQuant`]), dense otherwise. The forward counts the
//! MACs it actually executes, in the same convention as
//! [`crate::model::macs::report`] (weight matmuls exact, attention
//! `2·T·d_model` per token per block, tied LM head `vocab·d_model`), so
//! served MACs are directly comparable to the artifact's analytic
//! accounting.
//!
//! PR 9 moves the hot path onto the kernel layer in
//! [`crate::linalg::simd`]: weights (including the tied head) are packed
//! once at construction into the cache-aware panel layout, rope runs off a
//! shared precomputed [`RopeTable`], and every per-forward buffer lives in
//! a reusable [`ServeScratch`] arena — the `*_scratch` entry points do no
//! allocation in steady-state decode (asserted by
//! `tests/alloc_steady_state.rs`). All of it preserves the determinism
//! bar: packed/vectorized kernels are bitwise identical to the scalar
//! blocked kernels, for any thread count.

use anyhow::{bail, ensure, Result};

use crate::compress::CompressedModel;
use crate::decode::KvCache;
use crate::exec::ExecPool;
use crate::linalg::simd::{
    matmul_transb_packed_into, par_matmul_transb_packed_into, PackedWeight, RopeTable,
};
use crate::model::reference::{causal_attention_into, rmsnorm, rope_qk, silu};
use crate::model::ModelConfig;

use super::layer::{resize_zeroed, ServeLayer};
use super::ExecMode;

struct ServeBlock {
    attn_norm: Vec<f32>,
    ffn_norm: Vec<f32>,
    wq: ServeLayer,
    wk: ServeLayer,
    wv: ServeLayer,
    wo: ServeLayer,
    w_gate: ServeLayer,
    w_up: ServeLayer,
    w_down: ServeLayer,
}

impl ServeBlock {
    fn layers(&self) -> [&ServeLayer; 7] {
        [&self.wq, &self.wk, &self.wv, &self.wo, &self.w_gate, &self.w_up, &self.w_down]
    }
}

/// Reusable per-request scratch arena for the `*_scratch` forwards: every
/// per-forward buffer of the hot path, hoisted out of the loop. Buffers
/// are cleared and zero-resized per call, which never reallocates once
/// capacity covers the shapes — so a steady-state decode round does no
/// hot-path allocation. One arena per engine lane (the model itself stays
/// shared and immutable).
pub struct ServeScratch {
    h: Vec<f32>,
    norm: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    proj: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    mid: Vec<f32>,
    scores: Vec<f64>,
    /// Logits of the last `*_scratch` forward: `(seq, vocab)` rows from
    /// [`ServeModel::forward_cached_scratch`], a single `(vocab,)` row
    /// from the prefill/step variants.
    pub logits: Vec<f32>,
}

/// A compressed model in executable form.
pub struct ServeModel {
    cfg: ModelConfig,
    mode: ExecMode,
    embed: Vec<f32>,
    /// The tied LM head: `embed` packed once into panel layout.
    head: PackedWeight,
    final_norm: Vec<f32>,
    blocks: Vec<ServeBlock>,
    /// Shared rope frequencies/sin-cos band (prewarmed by
    /// [`ServeModel::scratch`] to keep decode reads lock-cheap and
    /// allocation-free).
    rope: RopeTable,
}

impl ServeModel {
    /// Build from an artifact. In [`ExecMode::Factored`] and
    /// [`ExecMode::FactoredQuant`], every matrix the artifact carries
    /// factors for executes in factored form (f32 or per-row int8
    /// respectively); matrices without factors (dense layers of the
    /// schedule, pruning artifacts, budget-1.0 identities) stay dense, so
    /// the modes coincide exactly when there is nothing to factor.
    pub fn from_artifact(cm: &CompressedModel, mode: ExecMode) -> Result<ServeModel> {
        let cfg = cm.params.config().clone();
        let layer = |block: usize, field: &str| -> Result<ServeLayer> {
            let name = format!("blocks.{block}.{field}");
            let t = cm.params.get(&name)?;
            let shape = t.shape();
            ensure!(shape.len() == 2, "`{name}`: rank-{} tensor", shape.len());
            let (d_out, d_in) = (shape[0], shape[1]);
            if mode != ExecMode::Dense {
                if let Some(f) = cm.factors.get(&name) {
                    ensure!(
                        f.d_out() == d_out && f.d_in() == d_in,
                        "factor `{name}`: {}x{} factors for a {d_out}x{d_in} layer",
                        f.d_out(),
                        f.d_in()
                    );
                    return Ok(match mode {
                        ExecMode::FactoredQuant => ServeLayer::factored_quant(f),
                        _ => ServeLayer::factored(f),
                    });
                }
            }
            Ok(ServeLayer::dense(t.as_f32()?.to_vec(), d_out, d_in))
        };
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for b in 0..cfg.n_layers {
            blocks.push(ServeBlock {
                attn_norm: cm.params.get(&format!("blocks.{b}.attn_norm"))?.as_f32()?.to_vec(),
                ffn_norm: cm.params.get(&format!("blocks.{b}.ffn_norm"))?.as_f32()?.to_vec(),
                wq: layer(b, "wq")?,
                wk: layer(b, "wk")?,
                wv: layer(b, "wv")?,
                wo: layer(b, "wo")?,
                w_gate: layer(b, "w_gate")?,
                w_up: layer(b, "w_up")?,
                w_down: layer(b, "w_down")?,
            });
        }
        let embed = cm.params.get("embed")?.as_f32()?.to_vec();
        let head = PackedWeight::pack(&embed, cfg.vocab, cfg.d_model);
        let rope = RopeTable::new(cfg.head_dim(), cfg.rope_theta);
        Ok(ServeModel {
            final_norm: cm.params.get("final_norm")?.as_f32()?.to_vec(),
            embed,
            head,
            rope,
            cfg,
            mode,
            blocks,
        })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    /// How many of the decomposable matrices execute in factored form
    /// (f32 or int8).
    pub fn n_factored(&self) -> usize {
        self.blocks.iter().flat_map(|b| b.layers()).filter(|l| l.is_factored()).count()
    }

    /// Logical weight-payload bytes this model holds for execution:
    /// embed + norms as f32, plus each [`ServeLayer`]'s stored form
    /// (f32 values, or int8 codes + per-row scales). Packing padding and
    /// the packed head mirror are excluded — they are layout artifacts.
    /// Matches the analytic [`crate::model::macs::weight_bytes`].
    pub fn weight_bytes(&self) -> u128 {
        let d = self.cfg.d_model as u128;
        let mut bytes = 4 * (self.cfg.vocab as u128) * d + 4 * d; // embed + final_norm
        for b in &self.blocks {
            bytes += 2 * 4 * d; // attn_norm + ffn_norm gains
            for l in b.layers() {
                bytes += l.weight_bytes();
            }
        }
        bytes
    }

    /// Build a scratch arena sized for this model and a KV window of
    /// `capacity` positions, prewarming the rope band so steady-state
    /// decode never takes the grow path.
    pub fn scratch(&self, capacity: usize) -> ServeScratch {
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let wide = d.max(cfg.d_ff);
        let max_rank = self
            .blocks
            .iter()
            .flat_map(|b| b.layers())
            .filter_map(|l| l.rank())
            .max()
            .unwrap_or(0);
        self.rope.ensure(capacity);
        ServeScratch {
            h: Vec::with_capacity(d),
            norm: Vec::with_capacity(d),
            q: Vec::with_capacity(d),
            k: Vec::with_capacity(d),
            v: Vec::with_capacity(d),
            attn: Vec::with_capacity(d),
            proj: Vec::with_capacity(wide),
            gate: Vec::with_capacity(wide),
            up: Vec::with_capacity(wide),
            mid: Vec::with_capacity(max_rank),
            scores: Vec::with_capacity(capacity.max(1)),
            logits: Vec::with_capacity(cfg.vocab),
        }
    }

    /// Analytic MACs for a `tokens`-long forward under this model's
    /// dispatch — what [`ServeModel::forward_logits`] will count.
    pub fn macs_for(&self, tokens: usize) -> u128 {
        let t = tokens as u128;
        let d = self.cfg.d_model as u128;
        let mut per_token: u128 = (self.cfg.vocab as u128) * d; // tied head
        for b in &self.blocks {
            for l in b.layers() {
                per_token += l.macs_per_row();
            }
            per_token += 2 * t * d; // attention scores + weighted values
        }
        per_token * t
    }

    /// Full-sequence forward: tokens -> ((seq, vocab) logits, MACs
    /// executed). Causal attention, positions from 0 (no KV cache — the
    /// engine batches whole requests).
    pub fn forward_logits(&self, tokens: &[i32]) -> Result<(Vec<f32>, u128)> {
        self.forward_logits_pooled(tokens, &ExecPool::serial())
    }

    /// [`ServeModel::forward_logits`] with every weight matmul (and the
    /// head) row-sharded over `pool` — bitwise identical to the serial
    /// forward for any thread count, so `--threads` is purely a
    /// performance knob.
    pub fn forward_logits_pooled(
        &self,
        tokens: &[i32],
        pool: &ExecPool,
    ) -> Result<(Vec<f32>, u128)> {
        let cfg = &self.cfg;
        let (d, nh) = (cfg.d_model, cfg.n_heads);
        debug_assert_eq!(cfg.head_dim() * nh, d);
        let seq = tokens.len();
        if seq == 0 {
            bail!("empty request");
        }
        let mut macs: u128 = 0;

        // embed
        let mut h = vec![0.0f32; seq * d];
        for (t, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            ensure!(tok < cfg.vocab, "token {tok} out of vocab");
            h[t * d..(t + 1) * d].copy_from_slice(&self.embed[tok * d..(tok + 1) * d]);
        }

        let mut buf = vec![0.0f32; seq * d];
        let mut scores = vec![0.0f64; seq];
        let mut attn_out = Vec::new();
        for block in &self.blocks {
            // ---- attention ----
            rmsnorm(&h, &block.attn_norm, cfg.norm_eps, &mut buf);
            let mut q = block.wq.apply_pooled(&buf, seq, pool);
            let mut k = block.wk.apply_pooled(&buf, seq, pool);
            let v = block.wv.apply_pooled(&buf, seq, pool);
            macs += seq as u128
                * (block.wq.macs_per_row() + block.wk.macs_per_row() + block.wv.macs_per_row());
            // same rope + causal-softmax math as ReferenceModel (shared
            // helpers; whole request at once, so pos0 = 0 and K/V are the
            // full projections)
            rope_qk(&mut q, &mut k, seq, d, nh, 0, &self.rope);
            resize_zeroed(&mut attn_out, seq * d);
            causal_attention_into(&q, &k, &v, seq, 0, d, nh, &mut scores, &mut attn_out);
            // accounting convention: 2·T·d per token per block (QKᵀ + PV),
            // matching `model::macs::report`
            macs += 2 * (seq as u128) * (seq as u128) * (d as u128);

            let o = block.wo.apply_pooled(&attn_out, seq, pool);
            macs += seq as u128 * block.wo.macs_per_row();
            for (hv, ov) in h.iter_mut().zip(&o) {
                *hv += ov;
            }

            // ---- ffn ----
            rmsnorm(&h, &block.ffn_norm, cfg.norm_eps, &mut buf);
            let gate = block.w_gate.apply_pooled(&buf, seq, pool);
            let up = block.w_up.apply_pooled(&buf, seq, pool);
            macs += seq as u128 * (block.w_gate.macs_per_row() + block.w_up.macs_per_row());
            let act: Vec<f32> = gate.iter().zip(&up).map(|(g, u)| silu(*g) * u).collect();
            let down = block.w_down.apply_pooled(&act, seq, pool);
            macs += seq as u128 * block.w_down.macs_per_row();
            for (hv, dv) in h.iter_mut().zip(&down) {
                *hv += dv;
            }
        }

        // tied head (packed — bitwise identical to the blocked kernel)
        rmsnorm(&h, &self.final_norm, cfg.norm_eps, &mut buf);
        let mut logits = vec![0.0f32; seq * cfg.vocab];
        par_matmul_transb_packed_into(&buf, &self.head, seq, pool, &mut logits);
        macs += (seq * cfg.vocab * d) as u128;
        Ok((logits, macs))
    }

    /// Incremental forward: consume `tokens` as the continuation of the
    /// sequence held in `cache` (appended at position `cache.pos()`),
    /// returning `(seq, vocab)` logits for every consumed position and the
    /// MACs executed. K/V projections land in the preallocated cache
    /// blocks; attention runs over the full cached window, so feeding a
    /// prompt chunk-by-chunk (or token-by-token) reproduces
    /// [`ServeModel::forward_logits`] on the concatenation.
    ///
    /// MAC accounting is the exact cached-decode convention of
    /// [`crate::model::macs::decode_step_macs`]: weight matmuls per their
    /// dense/factored dispatch, attention `2·(pos+1)·d_model` per block
    /// for the token at absolute position `pos`, tied head
    /// `vocab·d_model` — per consumed token.
    pub fn forward_cached(&self, tokens: &[i32], cache: &mut KvCache) -> Result<(Vec<f32>, u128)> {
        self.forward_cached_pooled(tokens, cache, &ExecPool::serial())
    }

    /// [`ServeModel::forward_cached`] with the weight matmuls row-sharded
    /// over `pool` — bitwise identical for any thread count.
    pub fn forward_cached_pooled(
        &self,
        tokens: &[i32],
        cache: &mut KvCache,
        pool: &ExecPool,
    ) -> Result<(Vec<f32>, u128)> {
        let mut s = self.scratch(cache.pos() + tokens.len());
        let macs = self.forward_cached_scratch(tokens, cache, pool, &mut s)?;
        Ok((std::mem::take(&mut s.logits), macs))
    }

    /// [`ServeModel::forward_cached_pooled`] over a caller-held
    /// [`ServeScratch`]: logits land in `scratch.logits` (`seq` rows).
    /// Allocation-free once the scratch capacities cover the shapes.
    pub fn forward_cached_scratch(
        &self,
        tokens: &[i32],
        cache: &mut KvCache,
        pool: &ExecPool,
        s: &mut ServeScratch,
    ) -> Result<u128> {
        let (d, vocab) = (self.cfg.d_model, self.cfg.vocab);
        let seq = tokens.len();
        let mut macs = self.cached_hidden_scratch(tokens, cache, pool, s)?;
        // tied head over every consumed position
        resize_zeroed(&mut s.logits, seq * vocab);
        par_matmul_transb_packed_into(&s.norm, &self.head, seq, pool, &mut s.logits);
        macs += (seq * vocab * d) as u128;
        cache.advance(seq);
        Ok(macs)
    }

    /// Prefill variant of [`ServeModel::forward_cached_pooled`] computing
    /// the LM head **only for the final position**: the scheduler samples
    /// nothing but the last row, and at real vocab sizes the `seq·vocab·d`
    /// head matmul dominates prefill — slicing it to `1·vocab·d` removes
    /// that waste. Returns the `(vocab,)` logits of the last consumed
    /// position plus the MACs executed; the last-row logits are bitwise
    /// identical to [`ServeModel::forward_cached`]'s final row (the head
    /// kernel is row-independent). Accounting matches
    /// [`crate::model::macs::decode_report`]'s prefill convention: per
    /// position weights + exact causal attention, plus one `vocab·d` head.
    pub fn forward_prefill(
        &self,
        tokens: &[i32],
        cache: &mut KvCache,
        pool: &ExecPool,
    ) -> Result<(Vec<f32>, u128)> {
        let mut s = self.scratch(cache.pos() + tokens.len());
        let macs = self.forward_prefill_scratch(tokens, cache, pool, &mut s)?;
        Ok((std::mem::take(&mut s.logits), macs))
    }

    /// [`ServeModel::forward_prefill`] over a caller-held scratch arena:
    /// the last-position `(vocab,)` logits land in `scratch.logits`.
    pub fn forward_prefill_scratch(
        &self,
        tokens: &[i32],
        cache: &mut KvCache,
        pool: &ExecPool,
        s: &mut ServeScratch,
    ) -> Result<u128> {
        let (d, vocab) = (self.cfg.d_model, self.cfg.vocab);
        let seq = tokens.len();
        let mut macs = self.cached_hidden_scratch(tokens, cache, pool, s)?;
        // tied head, last position only (m = 1 runs the serial kernel)
        resize_zeroed(&mut s.logits, vocab);
        let last = &s.norm[(seq - 1) * d..seq * d];
        matmul_transb_packed_into(last, &self.head, 1, &mut s.logits);
        macs += (vocab * d) as u128;
        cache.advance(seq);
        Ok(macs)
    }

    /// The shared cached-forward body: consume `tokens` through every
    /// block over `cache` (K/V written at `cache.pos()`, cursor **not**
    /// advanced — the head variants advance after reading), leaving the
    /// final-norm hidden states `(seq, d)` in `s.norm` and returning the
    /// MACs executed so far (weights + exact causal attention, no head).
    fn cached_hidden_scratch(
        &self,
        tokens: &[i32],
        cache: &mut KvCache,
        pool: &ExecPool,
        s: &mut ServeScratch,
    ) -> Result<u128> {
        let cfg = &self.cfg;
        let (d, nh) = (cfg.d_model, cfg.n_heads);
        let seq = tokens.len();
        if seq == 0 {
            bail!("empty chunk");
        }
        ensure!(
            cache.layers() == cfg.n_layers && cache.width() == d,
            "KV cache geometry ({} layers × d {}) does not match the model ({} × {d})",
            cache.layers(),
            cache.width(),
            cfg.n_layers,
        );
        ensure!(
            seq <= cache.remaining(),
            "KV cache overflow: {} cached + {seq} new > capacity {}",
            cache.pos(),
            cache.capacity()
        );
        let pos0 = cache.pos();
        let mut macs: u128 = 0;

        // embed
        resize_zeroed(&mut s.h, seq * d);
        for (t, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            ensure!(tok < cfg.vocab, "token {tok} out of vocab");
            s.h[t * d..(t + 1) * d].copy_from_slice(&self.embed[tok * d..(tok + 1) * d]);
        }

        resize_zeroed(&mut s.norm, seq * d);
        for (b, block) in self.blocks.iter().enumerate() {
            // ---- attention (over the cache) ----
            rmsnorm(&s.h, &block.attn_norm, cfg.norm_eps, &mut s.norm);
            block.wq.apply_into(&s.norm, seq, pool, &mut s.mid, &mut s.q);
            block.wk.apply_into(&s.norm, seq, pool, &mut s.mid, &mut s.k);
            block.wv.apply_into(&s.norm, seq, pool, &mut s.mid, &mut s.v);
            macs += seq as u128
                * (block.wq.macs_per_row() + block.wk.macs_per_row() + block.wv.macs_per_row());
            rope_qk(&mut s.q, &mut s.k, seq, d, nh, pos0, &self.rope);
            cache.write(b, pos0, &s.k, &s.v);
            let (kc, vc) = cache.view(b, pos0 + seq);
            s.scores.clear();
            s.scores.resize(pos0 + seq, 0.0);
            resize_zeroed(&mut s.attn, seq * d);
            causal_attention_into(&s.q, kc, vc, seq, pos0, d, nh, &mut s.scores, &mut s.attn);
            // exact causal cost: token t attends over pos0+t+1 cached keys
            for t in 0..seq {
                macs += 2 * (pos0 + t + 1) as u128 * d as u128;
            }

            block.wo.apply_into(&s.attn, seq, pool, &mut s.mid, &mut s.proj);
            macs += seq as u128 * block.wo.macs_per_row();
            for (hv, ov) in s.h.iter_mut().zip(&s.proj) {
                *hv += ov;
            }

            // ---- ffn ----
            rmsnorm(&s.h, &block.ffn_norm, cfg.norm_eps, &mut s.norm);
            block.w_gate.apply_into(&s.norm, seq, pool, &mut s.mid, &mut s.gate);
            block.w_up.apply_into(&s.norm, seq, pool, &mut s.mid, &mut s.up);
            macs += seq as u128 * (block.w_gate.macs_per_row() + block.w_up.macs_per_row());
            // silu·gate in place — same values the collecting loop produced
            for (g, u) in s.gate.iter_mut().zip(&s.up) {
                *g = silu(*g) * u;
            }
            block.w_down.apply_into(&s.gate, seq, pool, &mut s.mid, &mut s.proj);
            macs += seq as u128 * block.w_down.macs_per_row();
            for (hv, dv) in s.h.iter_mut().zip(&s.proj) {
                *hv += dv;
            }
        }

        // final norm (the head variants consume `s.norm`)
        rmsnorm(&s.h, &self.final_norm, cfg.norm_eps, &mut s.norm);
        Ok(macs)
    }

    /// One decode step: consume a single token through the cache and
    /// return its `(vocab,)` logits row plus the MACs executed — the unit
    /// of KV-cached autoregressive generation.
    pub fn forward_step(&self, token: i32, cache: &mut KvCache) -> Result<(Vec<f32>, u128)> {
        self.forward_cached(&[token], cache)
    }

    /// [`ServeModel::forward_step`] over a pool (single-row matmuls run
    /// serial either way; the pool matters only for factored layers with
    /// unusually wide ranks — kept for knob symmetry).
    pub fn forward_step_pooled(
        &self,
        token: i32,
        cache: &mut KvCache,
        pool: &ExecPool,
    ) -> Result<(Vec<f32>, u128)> {
        self.forward_cached_pooled(&[token], cache, pool)
    }

    /// [`ServeModel::forward_step_pooled`] over a caller-held scratch
    /// arena: the `(vocab,)` logits land in `scratch.logits`, and a
    /// steady-state round (warm scratch + prewarmed rope band) performs
    /// no heap allocation.
    pub fn forward_step_scratch(
        &self,
        token: i32,
        cache: &mut KvCache,
        pool: &ExecPool,
        s: &mut ServeScratch,
    ) -> Result<u128> {
        self.forward_cached_scratch(&[token], cache, pool, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::macs::{self, CompressionAccounting, WeightStore};
    use crate::model::ReferenceModel;
    use crate::serve::{demo_artifact, demo_config, synth_requests};

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
    }

    #[test]
    fn factored_forward_matches_dense_forward() {
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 11).unwrap();
        let dense = ServeModel::from_artifact(&cm, ExecMode::Dense).unwrap();
        let fact = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
        assert_eq!(dense.n_factored(), 0);
        assert!(fact.n_factored() > 0);
        for req in synth_requests(&cfg, 3, 20, 5) {
            let (ld, _) = dense.forward_logits(&req.tokens).unwrap();
            let (lf, _) = fact.forward_logits(&req.tokens).unwrap();
            let diff = max_abs_diff(&ld, &lf);
            assert!(diff <= 1e-4, "request {}: max |Δlogits| = {diff}", req.id);
        }
    }

    #[test]
    fn quantized_forward_tracks_factored_forward() {
        // the FactoredQuant contract: same dispatch/MACs as Factored,
        // logits within the stated tolerance (5% of the logit scale)
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 47).unwrap();
        let fact = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
        let quant = ServeModel::from_artifact(&cm, ExecMode::FactoredQuant).unwrap();
        assert_eq!(quant.n_factored(), fact.n_factored());
        assert_eq!(quant.mode(), ExecMode::FactoredQuant);
        for req in synth_requests(&cfg, 3, 16, 7) {
            let (lf, mf) = fact.forward_logits(&req.tokens).unwrap();
            let (lq, mq) = quant.forward_logits(&req.tokens).unwrap();
            assert_eq!(mf, mq, "quantization changes bytes, not MACs");
            let scale = lf.iter().fold(1.0f32, |m, &v| m.max(v.abs()));
            let diff = max_abs_diff(&lf, &lq);
            assert!(diff <= 0.05 * scale, "request {}: |Δ| {diff} vs scale {scale}", req.id);
        }
    }

    #[test]
    fn weight_bytes_match_analytic_accounting() {
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 53).unwrap();
        for (mode, store) in [
            (ExecMode::Dense, WeightStore::Dense),
            (ExecMode::Factored, WeightStore::Factored),
            (ExecMode::FactoredQuant, WeightStore::FactoredQuant),
        ] {
            let m = ServeModel::from_artifact(&cm, mode).unwrap();
            assert_eq!(mode.weight_store(), store);
            assert_eq!(
                m.weight_bytes(),
                macs::weight_bytes(&cfg, &cm.accounting, store),
                "{}",
                mode.name()
            );
        }
        let fact = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
        let quant = ServeModel::from_artifact(&cm, ExecMode::FactoredQuant).unwrap();
        assert!(quant.weight_bytes() < fact.weight_bytes());
    }

    #[test]
    fn dense_mode_matches_reference_model() {
        // the serving engine's dense path is an independent forward over
        // the same weights the ReferenceModel runs — they must agree
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 13).unwrap();
        let dense = ServeModel::from_artifact(&cm, ExecMode::Dense).unwrap();
        let reference = ReferenceModel::new(&cm.params);
        let tokens: Vec<i32> = (0..17).map(|i| (i * 3 % cfg.vocab as i32).max(0)).collect();
        let (ls, _) = dense.forward_logits(&tokens).unwrap();
        let lr = reference.forward_logits(&tokens).unwrap();
        let diff = max_abs_diff(&ls, &lr);
        assert!(diff <= 1e-4, "serve-dense vs reference: max |Δ| = {diff}");
    }

    #[test]
    fn served_macs_match_artifact_accounting() {
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 17).unwrap();
        let fact = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
        let dense = ServeModel::from_artifact(&cm, ExecMode::Dense).unwrap();
        for seq in [1usize, 7, 24] {
            let tokens: Vec<i32> = vec![1; seq];
            let (_, mf) = fact.forward_logits(&tokens).unwrap();
            let (_, md) = dense.forward_logits(&tokens).unwrap();
            assert_eq!(mf, macs::report(&cfg, &cm.accounting, seq).macs, "factored seq {seq}");
            assert_eq!(md, macs::report(&cfg, &CompressionAccounting::dense(), seq).macs);
            assert_eq!(mf, fact.macs_for(seq));
            assert_eq!(md, dense.macs_for(seq));
            assert!(mf < md, "factored must execute fewer MACs (seq {seq})");
        }
    }

    #[test]
    fn budget_one_artifact_serves_identically_in_all_modes() {
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 1.0, 19).unwrap();
        let dense = ServeModel::from_artifact(&cm, ExecMode::Dense).unwrap();
        let fact = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
        let quant = ServeModel::from_artifact(&cm, ExecMode::FactoredQuant).unwrap();
        assert_eq!(fact.n_factored(), 0, "identity artifact has nothing to factor");
        assert_eq!(quant.n_factored(), 0, "nothing to quantize either");
        let tokens: Vec<i32> = (0..12).map(|i| i % cfg.vocab as i32).collect();
        let (ld, md) = dense.forward_logits(&tokens).unwrap();
        let (lf, mf) = fact.forward_logits(&tokens).unwrap();
        let (lq, mq) = quant.forward_logits(&tokens).unwrap();
        assert_eq!(ld, lf, "identical dispatch must produce identical logits");
        assert_eq!(ld, lq, "quant mode with nothing to quantize is the dense dispatch");
        assert_eq!(md, mf);
        assert_eq!(md, mq);
    }

    #[test]
    fn rejects_bad_tokens() {
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 23).unwrap();
        let m = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
        assert!(m.forward_logits(&[]).is_err());
        assert!(m.forward_logits(&[cfg.vocab as i32]).is_err());
    }

    #[test]
    fn kv_cached_forward_matches_full_forward() {
        // chunked prefill + token-at-a-time through the cache must agree
        // with the from-scratch forward, in every execution mode
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 29).unwrap();
        let tokens = synth_requests(&cfg, 1, 18, 3)[0].tokens.clone();
        for mode in [ExecMode::Dense, ExecMode::Factored, ExecMode::FactoredQuant] {
            let m = ServeModel::from_artifact(&cm, mode).unwrap();
            let (full, _) = m.forward_logits(&tokens).unwrap();
            let mut cache = KvCache::new(&cfg, tokens.len());
            let mut inc = Vec::new();
            let split = 7;
            let (l, _) = m.forward_cached(&tokens[..split], &mut cache).unwrap();
            inc.extend(l);
            for &t in &tokens[split..] {
                let (l, _) = m.forward_step(t, &mut cache).unwrap();
                assert_eq!(l.len(), cfg.vocab);
                inc.extend(l);
            }
            assert_eq!(cache.pos(), tokens.len());
            let diff = max_abs_diff(&full, &inc);
            assert!(diff <= 1e-4, "{}: KV vs full max |Δ| = {diff}", mode.name());
        }
    }

    #[test]
    fn scratch_forwards_are_bitwise_identical_to_allocating_forwards() {
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 59).unwrap();
        let tokens = synth_requests(&cfg, 1, 14, 21)[0].tokens.clone();
        for mode in [ExecMode::Dense, ExecMode::Factored, ExecMode::FactoredQuant] {
            let m = ServeModel::from_artifact(&cm, mode).unwrap();
            let pool = ExecPool::serial();
            // allocating path
            let mut cache_a = KvCache::new(&cfg, tokens.len() + 4);
            let (want_pre, want_pre_macs) =
                m.forward_prefill(&tokens, &mut cache_a, &pool).unwrap();
            let mut want_steps = Vec::new();
            for t in 0..4 {
                want_steps.push(m.forward_step_pooled(t, &mut cache_a, &pool).unwrap());
            }
            // one reused scratch arena
            let mut s = m.scratch(tokens.len() + 4);
            let mut cache_b = KvCache::new(&cfg, tokens.len() + 4);
            let pre_macs = m.forward_prefill_scratch(&tokens, &mut cache_b, &pool, &mut s).unwrap();
            assert_eq!(s.logits, want_pre, "{}: prefill logits", mode.name());
            assert_eq!(pre_macs, want_pre_macs);
            for (t, (want_l, want_m)) in want_steps.iter().enumerate() {
                let macs =
                    m.forward_step_scratch(t as i32, &mut cache_b, &pool, &mut s).unwrap();
                assert_eq!(&s.logits, want_l, "{}: step {t}", mode.name());
                assert_eq!(macs, *want_m);
            }
        }
    }

    #[test]
    fn cached_macs_match_decode_accounting() {
        use crate::model::macs::decode_step_macs;
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 31).unwrap();
        let tokens = synth_requests(&cfg, 1, 12, 9)[0].tokens.clone();
        for (mode, acc) in [
            (ExecMode::Dense, CompressionAccounting::dense()),
            (ExecMode::Factored, cm.accounting.clone()),
            (ExecMode::FactoredQuant, cm.accounting.clone()),
        ] {
            let m = ServeModel::from_artifact(&cm, mode).unwrap();
            let mut cache = KvCache::new(&cfg, tokens.len());
            // prefill chunk of 5, then single steps — chunking must not
            // change the executed MACs
            let (_, m_prefill) = m.forward_cached(&tokens[..5], &mut cache).unwrap();
            let want_prefill: u128 = (0..5).map(|p| decode_step_macs(&cfg, &acc, p)).sum();
            assert_eq!(m_prefill, want_prefill, "{} prefill", mode.name());
            for (i, &t) in tokens[5..].iter().enumerate() {
                let (_, ms) = m.forward_step(t, &mut cache).unwrap();
                assert_eq!(ms, decode_step_macs(&cfg, &acc, 5 + i), "{} step {i}", mode.name());
            }
        }
    }

    #[test]
    fn pooled_forwards_are_bitwise_identical_to_serial() {
        use crate::exec::ExecPool;
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 41).unwrap();
        let tokens = synth_requests(&cfg, 1, 21, 13)[0].tokens.clone();
        for mode in [ExecMode::Dense, ExecMode::Factored, ExecMode::FactoredQuant] {
            let m = ServeModel::from_artifact(&cm, mode).unwrap();
            let (serial, macs_serial) = m.forward_logits(&tokens).unwrap();
            let mut cache_s = KvCache::new(&cfg, tokens.len());
            let (cached_serial, cmacs_serial) = m.forward_cached(&tokens, &mut cache_s).unwrap();
            for threads in [2usize, 3, 8] {
                let pool = ExecPool::new(threads);
                let (pooled, macs_pooled) = m.forward_logits_pooled(&tokens, &pool).unwrap();
                assert_eq!(pooled, serial, "{} t{threads}: full forward", mode.name());
                assert_eq!(macs_pooled, macs_serial);
                let mut cache_p = KvCache::new(&cfg, tokens.len());
                let (cached_pooled, cmacs_pooled) =
                    m.forward_cached_pooled(&tokens, &mut cache_p, &pool).unwrap();
                assert_eq!(cached_pooled, cached_serial, "{} t{threads}: cached", mode.name());
                assert_eq!(cmacs_pooled, cmacs_serial);
            }
        }
    }

    #[test]
    fn prefill_head_slice_matches_last_row_and_saves_head_macs() {
        use crate::exec::ExecPool;
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 43).unwrap();
        let tokens = synth_requests(&cfg, 1, 15, 17)[0].tokens.clone();
        let seq = tokens.len();
        let head = (cfg.vocab * cfg.d_model) as u128;
        for mode in [ExecMode::Dense, ExecMode::Factored] {
            let m = ServeModel::from_artifact(&cm, mode).unwrap();
            let mut full_cache = KvCache::new(&cfg, seq);
            let (full, full_macs) = m.forward_cached(&tokens, &mut full_cache).unwrap();
            let mut pre_cache = KvCache::new(&cfg, seq);
            let (last, pre_macs) =
                m.forward_prefill(&tokens, &mut pre_cache, &ExecPool::serial()).unwrap();
            assert_eq!(last.len(), cfg.vocab);
            // the sampled row is bitwise identical to the full head's last row
            assert_eq!(last[..], full[(seq - 1) * cfg.vocab..], "{}", mode.name());
            // and the head was billed once instead of `seq` times
            assert_eq!(pre_macs, full_macs - (seq as u128 - 1) * head, "{}", mode.name());
            assert_eq!(pre_cache.pos(), seq, "prefill advances the cache");
            // analytic accounting: decode_report's prefill convention
            let acc = match mode {
                ExecMode::Dense => CompressionAccounting::dense(),
                ExecMode::Factored => cm.accounting.clone(),
            };
            let rep = macs::decode_report(&cfg, &acc, seq, 1);
            assert_eq!(pre_macs, rep.prefill_macs, "{}", mode.name());
        }
    }

    #[test]
    fn cache_overflow_and_geometry_mismatch_are_errors() {
        let cfg = demo_config();
        let cm = demo_artifact(&cfg, 0.5, 37).unwrap();
        let m = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
        let mut cache = KvCache::new(&cfg, 3);
        assert!(m.forward_cached(&[1, 2, 3, 1], &mut cache).is_err(), "chunk > capacity");
        m.forward_cached(&[1, 2], &mut cache).unwrap();
        assert!(m.forward_cached(&[1, 2], &mut cache).is_err(), "overflow at pos 2/3");
        assert!(m.forward_step(1, &mut cache).is_ok(), "exactly filling is fine");
        assert!(m.forward_step(1, &mut cache).is_err(), "full cache rejects more");
        // cache built for a different geometry
        let other = crate::model::ModelConfig { n_layers: 1, ..cfg.clone() };
        let mut wrong = KvCache::new(&other, 8);
        assert!(m.forward_cached(&[1], &mut wrong).is_err());
        // empty chunks are rejected like empty requests
        let mut ok = KvCache::new(&cfg, 8);
        assert!(m.forward_cached(&[], &mut ok).is_err());
    }
}
