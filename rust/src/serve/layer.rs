//! Per-matrix dense/low-rank/quantized dispatch: the unit of
//! factored-form serving.
//!
//! A dense layer applies as `y = x·Wᵀ` (one `d_out×d_in` matmul); a
//! factored layer as `y = (x·W2ᵀ)·W1ᵀ` (two skinny matmuls through the
//! rank-r bottleneck), costing `r(d_in+d_out)` MACs per row instead of
//! `d_in·d_out`. Both store their weights packed into the cache-aware
//! panel layout ([`PackedWeight`], built once at construction) and run on
//! the fixed-lane-order packed kernel — bitwise identical to the unpacked
//! blocked kernel for any thread count. The quantized variant executes the
//! same factored dataflow over per-row int8 codes with f32 accumulation
//! ([`QuantizedWeight`]): same MAC count, ~4× fewer weight bytes, output
//! within a stated tolerance of (not bitwise equal to) the f32 factors.

use crate::exec::ExecPool;
use crate::linalg::simd::{
    par_matmul_transb_packed_into, par_matmul_transb_quant_into, PackedWeight, QuantizedWeight,
};
use crate::linalg::Matrix;
use crate::rom::decompose::RomFactors;

/// Clear and zero-fill `v` to `len` — allocation-free once `v`'s capacity
/// covers `len`, which is what keeps steady-state decode off the
/// allocator.
pub(crate) fn resize_zeroed(v: &mut Vec<f32>, len: usize) {
    v.clear();
    v.resize(len, 0.0);
}

/// One weight matrix, in whichever form it executes.
#[derive(Debug, Clone)]
pub enum ServeLayer {
    /// Packed `(d_out, d_in)` weight, applied as `x·Wᵀ`.
    Dense { w: PackedWeight, d_out: usize, d_in: usize },
    /// Factored pair: `w1` packed from row-major `(d_out, r)`, `w2` from
    /// `(r, d_in)`, applied as `(x·W2ᵀ)·W1ᵀ`.
    Factored { w1: PackedWeight, w2: PackedWeight, rank: usize, d_out: usize, d_in: usize },
    /// The factored pair under per-row symmetric int8 quantization —
    /// never a silent substitute: only `ExecMode::FactoredQuant` builds
    /// these.
    FactoredQuant {
        w1: QuantizedWeight,
        w2: QuantizedWeight,
        rank: usize,
        d_out: usize,
        d_in: usize,
    },
}

impl ServeLayer {
    pub fn dense(w: Vec<f32>, d_out: usize, d_in: usize) -> ServeLayer {
        assert_eq!(w.len(), d_out * d_in, "dense layer shape mismatch");
        ServeLayer::Dense { w: PackedWeight::pack(&w, d_out, d_in), d_out, d_in }
    }

    /// Factored layer from ROM factors (f64 → f32 for the serving path,
    /// mirroring how the dense path stores `W_eff` as f32).
    pub fn factored(f: &RomFactors) -> ServeLayer {
        let (rank, d_out, d_in) = (f.rank, f.d_out(), f.d_in());
        ServeLayer::Factored {
            w1: PackedWeight::pack(&f.w1.to_f32(), d_out, rank),
            w2: PackedWeight::pack(&f.w2.to_f32(), rank, d_in),
            rank,
            d_out,
            d_in,
        }
    }

    /// Factored layer from explicit `(d_out, r)` / `(r, d_in)` matrices
    /// (bench/test convenience).
    pub fn factored_from_matrices(w1: &Matrix, w2: &Matrix) -> ServeLayer {
        assert_eq!(w1.cols(), w2.rows(), "factor inner dims disagree");
        let (rank, d_out, d_in) = (w1.cols(), w1.rows(), w2.cols());
        ServeLayer::Factored {
            w1: PackedWeight::pack(&w1.to_f32(), d_out, rank),
            w2: PackedWeight::pack(&w2.to_f32(), rank, d_in),
            rank,
            d_out,
            d_in,
        }
    }

    /// Int8-quantized factored layer from ROM factors: quantize the same
    /// f32 factor matrices the [`ServeLayer::factored`] path packs.
    pub fn factored_quant(f: &RomFactors) -> ServeLayer {
        let (rank, d_out, d_in) = (f.rank, f.d_out(), f.d_in());
        ServeLayer::FactoredQuant {
            w1: QuantizedWeight::quantize(&f.w1.to_f32(), d_out, rank),
            w2: QuantizedWeight::quantize(&f.w2.to_f32(), rank, d_in),
            rank,
            d_out,
            d_in,
        }
    }

    pub fn d_out(&self) -> usize {
        match self {
            ServeLayer::Dense { d_out, .. }
            | ServeLayer::Factored { d_out, .. }
            | ServeLayer::FactoredQuant { d_out, .. } => *d_out,
        }
    }

    pub fn d_in(&self) -> usize {
        match self {
            ServeLayer::Dense { d_in, .. }
            | ServeLayer::Factored { d_in, .. }
            | ServeLayer::FactoredQuant { d_in, .. } => *d_in,
        }
    }

    /// True for both the f32 and the int8 factored forms (they execute
    /// the same two-matmul dataflow).
    pub fn is_factored(&self) -> bool {
        matches!(self, ServeLayer::Factored { .. } | ServeLayer::FactoredQuant { .. })
    }

    pub fn is_quantized(&self) -> bool {
        matches!(self, ServeLayer::FactoredQuant { .. })
    }

    pub fn rank(&self) -> Option<usize> {
        match self {
            ServeLayer::Dense { .. } => None,
            ServeLayer::Factored { rank, .. } | ServeLayer::FactoredQuant { rank, .. } => {
                Some(*rank)
            }
        }
    }

    /// Multiply-accumulates to apply this layer to one input row — the
    /// paper's `d1·d2` vs `r(d1+d2)` comparison, per layer. Quantization
    /// changes bytes, not MACs, so the factored forms agree.
    pub fn macs_per_row(&self) -> u128 {
        match self {
            ServeLayer::Dense { d_out, d_in, .. } => (*d_out * *d_in) as u128,
            ServeLayer::Factored { rank, d_out, d_in, .. }
            | ServeLayer::FactoredQuant { rank, d_out, d_in, .. } => {
                (*rank * (*d_out + *d_in)) as u128
            }
        }
    }

    /// Logical weight-payload bytes of this layer as stored for execution
    /// (f32 values, or int8 codes + per-row f32 scales; packing padding
    /// excluded — it is a layout artifact, not payload).
    pub fn weight_bytes(&self) -> u128 {
        match self {
            ServeLayer::Dense { d_out, d_in, .. } => 4 * (*d_out * *d_in) as u128,
            ServeLayer::Factored { rank, d_out, d_in, .. } => {
                4 * (*rank * (*d_out + *d_in)) as u128
            }
            ServeLayer::FactoredQuant { w1, w2, .. } => w1.logical_bytes() + w2.logical_bytes(),
        }
    }

    /// `y = x·Wᵀ` over `rows` row-major input rows of width `d_in`.
    pub fn apply(&self, x: &[f32], rows: usize) -> Vec<f32> {
        self.apply_pooled(x, rows, &ExecPool::serial())
    }

    /// [`ServeLayer::apply`] with the output rows sharded across `pool`'s
    /// workers — bitwise identical to the serial apply for any thread
    /// count (single-row inputs degenerate to the serial kernel).
    pub fn apply_pooled(&self, x: &[f32], rows: usize, pool: &ExecPool) -> Vec<f32> {
        let mut mid = Vec::new();
        let mut out = Vec::new();
        self.apply_into(x, rows, pool, &mut mid, &mut out);
        out
    }

    /// [`ServeLayer::apply_pooled`] over caller-provided scratch: `mid`
    /// holds the rank-r bottleneck activations of the factored forms,
    /// `out` the result. Both are cleared and zero-resized here, so once
    /// their capacities cover the layer the call allocates nothing.
    pub fn apply_into(
        &self,
        x: &[f32],
        rows: usize,
        pool: &ExecPool,
        mid: &mut Vec<f32>,
        out: &mut Vec<f32>,
    ) {
        debug_assert_eq!(x.len(), rows * self.d_in());
        resize_zeroed(out, rows * self.d_out());
        match self {
            ServeLayer::Dense { w, .. } => {
                par_matmul_transb_packed_into(x, w, rows, pool, out);
            }
            ServeLayer::Factored { w1, w2, rank, .. } => {
                resize_zeroed(mid, rows * rank);
                par_matmul_transb_packed_into(x, w2, rows, pool, mid);
                par_matmul_transb_packed_into(mid, w1, rows, pool, out);
            }
            ServeLayer::FactoredQuant { w1, w2, rank, .. } => {
                resize_zeroed(mid, rows * rank);
                par_matmul_transb_quant_into(x, w2, rows, pool, mid);
                par_matmul_transb_quant_into(mid, w1, rows, pool, out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::rom::decompose::decompose_weight;
    use crate::util::Rng;

    fn random_factors(d_out: usize, d_in: usize, n: usize, rank: usize, seed: u64) -> RomFactors {
        let mut rng = Rng::new(seed);
        let w = Matrix::from_fn(d_out, d_in, |_, _| rng.normal() * 0.1);
        let y = Matrix::from_fn(n, d_out, |_, _| rng.normal());
        let cov = matmul(&y.transpose(), &y);
        decompose_weight(&w, &cov, rank).unwrap()
    }

    #[test]
    fn factored_apply_matches_effective_weight_apply() {
        // the acceptance bar: factored execution ≈ re-densified execution
        // to ≤1e-5 on random inputs
        for (seed, (d_out, d_in, rank)) in [(70, 16, 3), (33, 47, 7), (64, 64, 21)].iter().enumerate()
        {
            let f = random_factors(*d_out, *d_in, 120, *rank, seed as u64);
            let weff = f.effective_weight();
            let dense = ServeLayer::dense(weff.to_f32(), *d_out, *d_in);
            let fact = ServeLayer::factored(&f);
            assert!(fact.is_factored() && !dense.is_factored());
            assert_eq!(fact.rank(), Some(*rank));

            let mut rng = Rng::new(seed as u64 + 100);
            let rows = 33;
            let x: Vec<f32> = (0..rows * d_in).map(|_| rng.normal() as f32).collect();
            let yd = dense.apply(&x, rows);
            let yf = fact.apply(&x, rows);
            assert_eq!(yd.len(), rows * d_out);
            let max_abs = yd
                .iter()
                .zip(&yf)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(max_abs < 1e-5, "d{d_out}x{d_in} r{rank}: max |Δ| = {max_abs}");
        }
    }

    #[test]
    fn mac_accounting_matches_paper_formula() {
        let f = random_factors(20, 12, 80, 4, 0);
        let dense = ServeLayer::dense(f.effective_weight().to_f32(), 20, 12);
        let fact = ServeLayer::factored(&f);
        let quant = ServeLayer::factored_quant(&f);
        assert_eq!(dense.macs_per_row(), 20 * 12);
        assert_eq!(fact.macs_per_row(), 4 * (20 + 12));
        assert_eq!(quant.macs_per_row(), fact.macs_per_row());
        assert!(fact.macs_per_row() < dense.macs_per_row());
    }

    #[test]
    fn weight_byte_accounting_counts_codes_and_scales() {
        let f = random_factors(20, 12, 80, 4, 1);
        let dense = ServeLayer::dense(f.effective_weight().to_f32(), 20, 12);
        let fact = ServeLayer::factored(&f);
        let quant = ServeLayer::factored_quant(&f);
        assert_eq!(dense.weight_bytes(), 4 * 20 * 12);
        assert_eq!(fact.weight_bytes(), 4 * 4 * (20 + 12));
        // w1: 20×4 codes + 20 scales; w2: 4×12 codes + 4 scales
        assert_eq!(quant.weight_bytes(), (20 * 4 + 4 * 20) as u128 + (4 * 12 + 4 * 4) as u128);
        assert!(quant.weight_bytes() < fact.weight_bytes());
        assert!(quant.is_quantized() && !fact.is_quantized());
    }

    #[test]
    fn full_rank_factored_apply_is_near_exact() {
        let f = random_factors(10, 8, 60, 10, 3);
        let dense = ServeLayer::dense(f.effective_weight().to_f32(), 10, 8);
        let fact = ServeLayer::factored(&f);
        let x: Vec<f32> = (0..8).map(|i| i as f32 * 0.25 - 1.0).collect();
        for (a, b) in dense.apply(&x, 1).iter().zip(fact.apply(&x, 1)) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn quantized_apply_tracks_f32_factored_apply() {
        let f = random_factors(24, 18, 90, 6, 7);
        let fact = ServeLayer::factored(&f);
        let quant = ServeLayer::factored_quant(&f);
        assert_eq!(quant.rank(), Some(6));
        let mut rng = Rng::new(11);
        let rows = 5;
        let x: Vec<f32> = (0..rows * 18).map(|_| rng.normal() as f32).collect();
        let yf = fact.apply(&x, rows);
        let yq = quant.apply(&x, rows);
        let scale = yf.iter().fold(1.0f32, |m, &v| m.max(v.abs()));
        let max_abs =
            yf.iter().zip(&yq).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max_abs <= 0.05 * scale, "max |Δ| = {max_abs} vs scale {scale}");
    }

    #[test]
    fn apply_into_reuses_scratch_without_reallocating() {
        let f = random_factors(16, 12, 60, 4, 5);
        let fact = ServeLayer::factored(&f);
        let mut rng = Rng::new(21);
        let x: Vec<f32> = (0..12).map(|_| rng.normal() as f32).collect();
        let pool = ExecPool::serial();
        let (mut mid, mut out) = (Vec::new(), Vec::new());
        fact.apply_into(&x, 1, &pool, &mut mid, &mut out);
        let want = fact.apply(&x, 1);
        assert_eq!(out, want);
        let (mid_cap, out_cap) = (mid.capacity(), out.capacity());
        let (mid_ptr, out_ptr) = (mid.as_ptr(), out.as_ptr());
        for _ in 0..3 {
            fact.apply_into(&x, 1, &pool, &mut mid, &mut out);
        }
        assert_eq!(out, want, "repeated in-place applies stay bitwise identical");
        assert_eq!((mid.capacity(), out.capacity()), (mid_cap, out_cap));
        assert_eq!((mid.as_ptr(), out.as_ptr()), (mid_ptr, out_ptr), "no reallocation");
    }
}
