//! Per-matrix dense/low-rank dispatch: the unit of factored-form serving.
//!
//! A dense layer applies as `y = x·Wᵀ` (one `d_out×d_in` matmul); a
//! factored layer as `y = (x·W2ᵀ)·W1ᵀ` (two skinny matmuls through the
//! rank-r bottleneck), costing `r(d_in+d_out)` MACs per row instead of
//! `d_in·d_out`. Both run on the cache-blocked f32 kernel
//! ([`crate::linalg::matmul_transb_blocked_f32`]).

use crate::exec::ExecPool;
use crate::linalg::{par_matmul_transb_blocked_f32, Matrix};
use crate::rom::decompose::RomFactors;

/// One weight matrix, in whichever form it executes.
#[derive(Debug, Clone)]
pub enum ServeLayer {
    /// Row-major `(d_out, d_in)` weight, applied as `x·Wᵀ`.
    Dense { w: Vec<f32>, d_out: usize, d_in: usize },
    /// Factored pair: `w1` row-major `(d_out, r)`, `w2` row-major
    /// `(r, d_in)`, applied as `(x·W2ᵀ)·W1ᵀ`.
    Factored { w1: Vec<f32>, w2: Vec<f32>, rank: usize, d_out: usize, d_in: usize },
}

impl ServeLayer {
    pub fn dense(w: Vec<f32>, d_out: usize, d_in: usize) -> ServeLayer {
        assert_eq!(w.len(), d_out * d_in, "dense layer shape mismatch");
        ServeLayer::Dense { w, d_out, d_in }
    }

    /// Factored layer from ROM factors (f64 → f32 for the serving path,
    /// mirroring how the dense path stores `W_eff` as f32).
    pub fn factored(f: &RomFactors) -> ServeLayer {
        ServeLayer::Factored {
            w1: f.w1.to_f32(),
            w2: f.w2.to_f32(),
            rank: f.rank,
            d_out: f.d_out(),
            d_in: f.d_in(),
        }
    }

    /// Factored layer from explicit `(d_out, r)` / `(r, d_in)` matrices
    /// (bench/test convenience).
    pub fn factored_from_matrices(w1: &Matrix, w2: &Matrix) -> ServeLayer {
        assert_eq!(w1.cols(), w2.rows(), "factor inner dims disagree");
        ServeLayer::Factored {
            rank: w1.cols(),
            d_out: w1.rows(),
            d_in: w2.cols(),
            w1: w1.to_f32(),
            w2: w2.to_f32(),
        }
    }

    pub fn d_out(&self) -> usize {
        match self {
            ServeLayer::Dense { d_out, .. } | ServeLayer::Factored { d_out, .. } => *d_out,
        }
    }

    pub fn d_in(&self) -> usize {
        match self {
            ServeLayer::Dense { d_in, .. } | ServeLayer::Factored { d_in, .. } => *d_in,
        }
    }

    pub fn is_factored(&self) -> bool {
        matches!(self, ServeLayer::Factored { .. })
    }

    pub fn rank(&self) -> Option<usize> {
        match self {
            ServeLayer::Dense { .. } => None,
            ServeLayer::Factored { rank, .. } => Some(*rank),
        }
    }

    /// Multiply-accumulates to apply this layer to one input row — the
    /// paper's `d1·d2` vs `r(d1+d2)` comparison, per layer.
    pub fn macs_per_row(&self) -> u128 {
        match self {
            ServeLayer::Dense { d_out, d_in, .. } => (*d_out * *d_in) as u128,
            ServeLayer::Factored { rank, d_out, d_in, .. } => (*rank * (*d_out + *d_in)) as u128,
        }
    }

    /// `y = x·Wᵀ` over `rows` row-major input rows of width `d_in`.
    pub fn apply(&self, x: &[f32], rows: usize) -> Vec<f32> {
        self.apply_pooled(x, rows, &ExecPool::serial())
    }

    /// [`ServeLayer::apply`] with the output rows sharded across `pool`'s
    /// workers — bitwise identical to the serial apply for any thread
    /// count (single-row inputs degenerate to the serial kernel).
    pub fn apply_pooled(&self, x: &[f32], rows: usize, pool: &ExecPool) -> Vec<f32> {
        debug_assert_eq!(x.len(), rows * self.d_in());
        match self {
            ServeLayer::Dense { w, d_out, d_in } => {
                par_matmul_transb_blocked_f32(x, w, rows, *d_in, *d_out, pool)
            }
            ServeLayer::Factored { w1, w2, rank, d_out, d_in } => {
                let t = par_matmul_transb_blocked_f32(x, w2, rows, *d_in, *rank, pool);
                par_matmul_transb_blocked_f32(&t, w1, rows, *rank, *d_out, pool)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::rom::decompose::decompose_weight;
    use crate::util::Rng;

    fn random_factors(d_out: usize, d_in: usize, n: usize, rank: usize, seed: u64) -> RomFactors {
        let mut rng = Rng::new(seed);
        let w = Matrix::from_fn(d_out, d_in, |_, _| rng.normal() * 0.1);
        let y = Matrix::from_fn(n, d_out, |_, _| rng.normal());
        let cov = matmul(&y.transpose(), &y);
        decompose_weight(&w, &cov, rank).unwrap()
    }

    #[test]
    fn factored_apply_matches_effective_weight_apply() {
        // the acceptance bar: factored execution ≈ re-densified execution
        // to ≤1e-5 on random inputs
        for (seed, (d_out, d_in, rank)) in [(70, 16, 3), (33, 47, 7), (64, 64, 21)].iter().enumerate()
        {
            let f = random_factors(*d_out, *d_in, 120, *rank, seed as u64);
            let weff = f.effective_weight();
            let dense = ServeLayer::dense(weff.to_f32(), *d_out, *d_in);
            let fact = ServeLayer::factored(&f);
            assert!(fact.is_factored() && !dense.is_factored());
            assert_eq!(fact.rank(), Some(*rank));

            let mut rng = Rng::new(seed as u64 + 100);
            let rows = 33;
            let x: Vec<f32> = (0..rows * d_in).map(|_| rng.normal() as f32).collect();
            let yd = dense.apply(&x, rows);
            let yf = fact.apply(&x, rows);
            assert_eq!(yd.len(), rows * d_out);
            let max_abs = yd
                .iter()
                .zip(&yf)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(max_abs < 1e-5, "d{d_out}x{d_in} r{rank}: max |Δ| = {max_abs}");
        }
    }

    #[test]
    fn mac_accounting_matches_paper_formula() {
        let f = random_factors(20, 12, 80, 4, 0);
        let dense = ServeLayer::dense(f.effective_weight().to_f32(), 20, 12);
        let fact = ServeLayer::factored(&f);
        assert_eq!(dense.macs_per_row(), 20 * 12);
        assert_eq!(fact.macs_per_row(), 4 * (20 + 12));
        assert!(fact.macs_per_row() < dense.macs_per_row());
    }

    #[test]
    fn full_rank_factored_apply_is_near_exact() {
        let f = random_factors(10, 8, 60, 10, 3);
        let dense = ServeLayer::dense(f.effective_weight().to_f32(), 10, 8);
        let fact = ServeLayer::factored(&f);
        let x: Vec<f32> = (0..8).map(|i| i as f32 * 0.25 - 1.0).collect();
        for (a, b) in dense.apply(&x, 1).iter().zip(fact.apply(&x, 1)) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
