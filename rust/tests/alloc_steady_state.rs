//! Steady-state decode does no hot-path allocation.
//!
//! The serving hot path runs through a per-request scratch arena
//! ([`llm_rom::serve::ServeScratch`]): every buffer a forward needs is
//! sized once at admission and reused for every subsequent decode step.
//! This test pins that contract with a counting global allocator — after
//! a short warm-up (the rope table band and any Vec growth settle), a
//! run of `forward_step_scratch` calls must perform exactly zero
//! allocations.
//!
//! Lives alone in this file: a counting `#[global_allocator]` is
//! process-wide, and sharing the binary with unrelated concurrent tests
//! would make the delta meaningless.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use llm_rom::decode::KvCache;
use llm_rom::exec::ExecPool;
use llm_rom::serve::{demo_artifact, demo_config, ExecMode, ServeModel};

#[test]
fn steady_state_decode_allocates_nothing() {
    const WARMUP: usize = 4;
    const STEPS: usize = 20;
    let prompt = [1i32, 2, 3, 5, 8];
    let capacity = prompt.len() + WARMUP + STEPS + 1;

    let cfg = demo_config();
    let cm = demo_artifact(&cfg, 0.5, 0xA110C).unwrap();
    // serial pool: worker threads park on channels whose wakeups must not
    // count against the hot path's allocation budget
    let pool = ExecPool::new(1);

    for mode in [ExecMode::Dense, ExecMode::Factored, ExecMode::FactoredQuant] {
        let model = ServeModel::from_artifact(&cm, mode).unwrap();
        let mut cache = KvCache::new(&cfg, capacity);
        let mut scratch = model.scratch(capacity);
        model.forward_prefill_scratch(&prompt, &mut cache, &pool, &mut scratch).unwrap();
        let mut tok = 0i32;
        // warm up: first steps may still grow buffers toward capacity
        for _ in 0..WARMUP {
            model.forward_step_scratch(tok, &mut cache, &pool, &mut scratch).unwrap();
            tok = (tok + 1) % cfg.vocab as i32;
        }

        let before = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..STEPS {
            model.forward_step_scratch(tok, &mut cache, &pool, &mut scratch).unwrap();
            tok = (tok + 1) % cfg.vocab as i32;
        }
        let delta = ALLOCS.load(Ordering::Relaxed) - before;
        assert_eq!(
            delta, 0,
            "[{}] steady-state decode allocated {delta} times over {STEPS} steps",
            mode.name()
        );
    }
}
