//! Loopback integration tests for the HTTP/SSE daemon — fully offline,
//! client and server in one process on a synthetic factored artifact
//! (no `artifacts/` and no PJRT needed).
//!
//! Each test drives a bound [`Daemon`] through real sockets and asserts
//! the wire-level contracts: SSE streams mirror the in-process event
//! stream byte for byte, a saturated queue sheds `429` instead of
//! hanging, a mid-stream disconnect cancels the request and frees its
//! slot, `POST /admin/drain` finishes in-flight work before exiting, and
//! malformed bodies get structured `4xx` envelopes — never a panic.

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use llm_rom::daemon::{wire, Daemon, DaemonConfig, DaemonControl, DaemonReport, HttpClient};
use llm_rom::engine::{self, EngineConfig, EngineCore, InferenceRequest};
use llm_rom::serve::{demo_artifact, demo_config, ExecMode, ServeModel};
use llm_rom::util::json::Json;

const SEED: u64 = 11;

/// Bind a daemon on an ephemeral loopback port, run the client script
/// against it, then drain and join. Draining unconditionally (drain is
/// idempotent and overrides the pause hook) keeps the scope joinable
/// even when the script fails mid-run — the failure surfaces as a test
/// panic, not a hang.
fn run_daemon(
    engine: EngineConfig,
    script: impl FnOnce(SocketAddr, &DaemonControl) -> Result<()>,
) -> DaemonReport {
    let cfg = demo_config();
    let cm = demo_artifact(&cfg, 0.5, SEED).unwrap();
    let model = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
    let daemon = Daemon::bind(
        &model,
        DaemonConfig { addr: "127.0.0.1:0".into(), engine, retry_after_s: 2, obs: true },
    )
    .unwrap();
    let ctl = daemon.control();
    let addr = daemon.addr();
    std::thread::scope(|s| {
        let srv = s.spawn(move || daemon.serve());
        let out = script(addr, &ctl);
        ctl.drain();
        let report = srv.join().expect("daemon thread panicked");
        out.expect("client script failed");
        report.expect("daemon serve failed")
    })
}

fn small_engine() -> EngineConfig {
    EngineConfig {
        slots: 2,
        queue_cap: 4,
        max_new: 5,
        capacity: 6 + 64,
        seed: SEED,
        eos: None,
        ..EngineConfig::default()
    }
}

fn gen_body(prompt: &[i32], max_new: usize, stream: bool) -> Json {
    wire::obj(vec![
        ("prompt", Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect())),
        ("max_new", Json::Num(max_new as f64)),
        ("stream", Json::Bool(stream)),
    ])
}

/// Read SSE frames off a streaming client until the `finished` frame.
fn drain_sse(client: &mut HttpClient) -> Result<Vec<(String, String)>> {
    let mut frames = Vec::new();
    while let Some(f) = client.next_sse_frame()? {
        let done = f.event == "finished";
        frames.push((f.event, f.data));
        if done {
            break;
        }
    }
    ensure!(
        frames.last().is_some_and(|(e, _)| e == "finished"),
        "stream ended without a finished frame"
    );
    Ok(frames)
}

/// Poll `/healthz` until `pred` accepts the payload (or 10s pass).
fn poll_healthz(addr: SocketAddr, what: &str, pred: impl Fn(&Json) -> bool) -> Result<()> {
    let mut c = HttpClient::connect(addr)?;
    let t0 = Instant::now();
    loop {
        let h = c.get("/healthz")?.json()?;
        if pred(&h) {
            return Ok(());
        }
        ensure!(t0.elapsed() < Duration::from_secs(10), "timed out waiting for {what}: {h}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn sse_streams_mirror_the_in_process_event_stream() {
    let engine_cfg = small_engine();
    let cfg = demo_config();
    let cm = demo_artifact(&cfg, 0.5, SEED).unwrap();
    let model = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
    let prompts = engine::synth_token_streams(&cfg, 3, 6, SEED);

    // in-process reference: same requests, same config, one session
    let mut session = EngineCore::new(&model, engine_cfg).session();
    let mut expected: BTreeMap<usize, Vec<(String, String)>> = BTreeMap::new();
    for (id, p) in prompts.iter().enumerate() {
        let back = session.try_submit(InferenceRequest::generate(id, p.clone(), Some(5))).unwrap();
        assert!(back.is_none(), "queue cap 4 fits 3 requests");
    }
    while session.has_work() {
        session.step().unwrap();
        for ev in session.take_events() {
            let (e, d) = wire::event_sse(&ev);
            expected.entry(ev.id).or_default().push((e.to_string(), d));
        }
    }
    let (reference, _) = session.finish();
    assert_eq!(reference.len(), 3);

    let report = run_daemon(engine_cfg, |addr, _ctl| {
        for (id, p) in prompts.iter().enumerate() {
            let mut c = HttpClient::connect(addr)?;
            let resp = c.post_json("/v1/generate", &gen_body(p, 5, true))?;
            ensure!(resp.status == 200 && resp.is_sse(), "stream {id}: status {}", resp.status);
            let frames = drain_sse(&mut c)?;
            ensure!(
                frames == expected[&id],
                "stream {id}: SSE transcript diverges from the in-process events"
            );
        }
        Ok(())
    });
    assert_eq!(report.stats.requests, 3);
    assert_eq!(report.sse_streams, 3);
    assert_eq!(report.stats.generated_tokens, 15);
}

#[test]
fn saturated_queue_sheds_429_instead_of_hanging() {
    let engine_cfg = EngineConfig { slots: 1, queue_cap: 2, ..small_engine() };
    let cfg = demo_config();
    let prompts = engine::synth_token_streams(&cfg, 3, 6, SEED);

    let report = run_daemon(engine_cfg, |addr, ctl| {
        // freeze admission so queue occupancy is deterministic
        ctl.pause();
        let mut queued = Vec::new();
        for (id, p) in prompts.iter().take(2).enumerate() {
            let mut c = HttpClient::connect(addr)?;
            let resp = c.post_json("/v1/generate", &gen_body(p, 3, true))?;
            ensure!(resp.status == 200, "queued stream {id}: status {}", resp.status);
            queued.push(c);
        }
        let t0 = Instant::now();
        while ctl.snapshot().queue_depth < 2 {
            ensure!(t0.elapsed() < Duration::from_secs(10), "queue never filled");
            std::thread::sleep(Duration::from_millis(2));
        }
        let mut c = HttpClient::connect(addr)?;
        let resp = c.post_json("/v1/generate", &gen_body(&prompts[2], 3, true))?;
        ensure!(resp.status == 429, "over-capacity: status {}", resp.status);
        ensure!(resp.header("retry-after") == Some("2"), "429 must carry Retry-After");
        let env = resp.json()?;
        ensure!(env.get("error")?.get("status")?.as_usize()? == 429, "structured envelope");
        ctl.resume();
        for mut c in queued {
            drain_sse(&mut c)?;
        }
        Ok(())
    });
    assert_eq!(report.shed_429, 1);
    assert_eq!(report.stats.requests, 2, "shed request never reached the engine");
}

#[test]
fn mid_stream_disconnect_cancels_and_frees_the_slot() {
    let engine_cfg = EngineConfig { slots: 1, ..small_engine() };
    let cfg = demo_config();
    let prompts = engine::synth_token_streams(&cfg, 2, 6, SEED);

    let report = run_daemon(engine_cfg, |addr, _ctl| {
        let mut doomed = HttpClient::connect(addr)?;
        let resp = doomed.post_json("/v1/generate", &gen_body(&prompts[0], 64, true))?;
        ensure!(resp.status == 200 && resp.is_sse(), "doomed stream: status {}", resp.status);
        let mut seen = 0usize;
        while let Some(f) = doomed.next_sse_frame()? {
            if f.event == "token" {
                seen += 1;
                if seen == 2 {
                    break;
                }
            }
        }
        ensure!(seen == 2, "doomed stream ended before 2 tokens");
        drop(doomed); // hang up mid-stream
        poll_healthz(addr, "disconnect cancellation", |h| {
            let cancelled = h.get("cancelled").and_then(|v| v.as_usize()).unwrap_or(0);
            let active = h.get("active").and_then(|v| v.as_usize()).unwrap_or(1);
            cancelled == 1 && active == 0
        })?;
        // the freed slot takes new work to completion
        let mut c = HttpClient::connect(addr)?;
        let resp = c.post_json("/v1/generate", &gen_body(&prompts[1], 3, true))?;
        ensure!(resp.status == 200, "post-cancel stream: status {}", resp.status);
        drain_sse(&mut c)?;
        Ok(())
    });
    assert_eq!(report.stats.cancelled, 1);
    assert_eq!(report.disconnect_cancels, 1);
    assert_eq!(report.stats.requests, 2, "cancelled + completed both retired");
}

#[test]
fn drain_finishes_in_flight_work_and_refuses_new_work() {
    let engine_cfg = EngineConfig { slots: 1, ..small_engine() };
    let cfg = demo_config();
    let prompts = engine::synth_token_streams(&cfg, 2, 6, SEED);

    let report = run_daemon(engine_cfg, |addr, ctl| {
        // park one stream in the queue so it is in flight when drain lands
        ctl.pause();
        let mut inflight = HttpClient::connect(addr)?;
        let resp = inflight.post_json("/v1/generate", &gen_body(&prompts[0], 4, true))?;
        ensure!(resp.status == 200, "in-flight stream: status {}", resp.status);

        let mut admin = HttpClient::connect(addr)?;
        let resp = admin.get("/readyz")?;
        ensure!(resp.status == 200, "readyz before drain: status {}", resp.status);
        let resp = admin.post_json("/admin/drain", &wire::obj(vec![]))?;
        ensure!(resp.status == 200, "drain: status {}", resp.status);
        ensure!(ctl.draining(), "control must observe draining");
        let resp = admin.get("/readyz")?;
        ensure!(resp.status == 503, "readyz while draining: status {}", resp.status);
        let resp = admin.post_json("/v1/generate", &gen_body(&prompts[1], 4, true))?;
        ensure!(resp.status == 503, "post-drain submission: status {}", resp.status);
        let env = resp.json()?;
        ensure!(env.get("error")?.get("status")?.as_usize()? == 503, "structured envelope");

        // drain overrides the pause hook: the parked stream still finishes
        let frames = drain_sse(&mut inflight)?;
        ensure!(frames.iter().filter(|(e, _)| e == "token").count() == 4, "4 tokens");
        Ok(())
    });
    assert_eq!(report.stats.requests, 1, "in-flight work retired");
    assert_eq!(report.shed_503, 1, "post-drain submission refused");
}

#[test]
fn malformed_requests_get_structured_envelopes_never_a_panic() {
    let engine_cfg = small_engine();
    let cfg = demo_config();
    let prompts = engine::synth_token_streams(&cfg, 1, 6, SEED);

    let report = run_daemon(engine_cfg, |addr, _ctl| {
        let mut c = HttpClient::connect(addr)?;
        let bad: &[&[u8]] = &[
            b"{not json",
            br#"{"prompt": [1], "bogus": true}"#,
            br#"{"prompt": [99999]}"#,
            br#"{"max_new": 4}"#,
        ];
        for body in bad {
            let resp = c.post_raw("/v1/generate", body)?;
            ensure!(resp.status == 400, "{:?}: status {}", String::from_utf8_lossy(body), resp.status);
            let env = resp.json()?;
            ensure!(env.get("error")?.get("status")?.as_usize()? == 400, "structured envelope");
        }
        // routing errors are envelopes too
        let resp = c.get("/v1/generate")?;
        ensure!(resp.status == 405, "GET on a POST endpoint: status {}", resp.status);
        let resp = c.post_json("/v1/nope", &wire::obj(vec![]))?;
        ensure!(resp.status == 404, "unknown endpoint: status {}", resp.status);
        // and the daemon is still healthy afterwards
        let resp = c.get("/healthz")?;
        ensure!(resp.status == 200, "healthz after abuse: status {}", resp.status);
        let resp = c.post_json("/v1/generate", &gen_body(&prompts[0], 3, false))?;
        ensure!(resp.status == 200, "valid request after abuse: status {}", resp.status);
        ensure!(resp.json()?.get("tokens")?.as_arr()?.len() == 3, "unary envelope");
        Ok(())
    });
    assert_eq!(report.bad_requests, 4, "each malformed body counted once");
    assert_eq!(report.stats.requests, 1, "only the valid request reached the engine");
}
