//! Integration tests across runtime + model + rom + prune + compress +
//! eval.
//!
//! These need `artifacts/` (run `make artifacts`) AND a real PJRT backend
//! (the `xla` stub in `rust/vendor/xla` compiles everywhere but cannot
//! execute); each test skips politely — with a clear message — when either
//! is missing, so `cargo test` stays green on a fresh clone. The PJRT
//! client is not `Send` (Rc internals in the xla crate), so the runtime is
//! shared per test thread via `thread_local` — with the default
//! single-core harness that is one client and one warm compile cache.

use llm_rom::compress::{CompressionSession, EmptyStream, METHODS};
use llm_rom::coordinator::{Experiment, ExperimentConfig};
use llm_rom::data::{CalibSource, Split, Task, TaskKind};
use llm_rom::eval::Evaluator;
use llm_rom::model::{macs, ModelConfig, ParamStore};
use llm_rom::prune::{Importance, Pruner};
use llm_rom::rom::{ModuleSchedule, RomConfig, RomPipeline};
use llm_rom::runtime::Runtime;
use llm_rom::tensor::Tensor;
use llm_rom::util::Rng;

thread_local! {
    static RT: Option<&'static Runtime> = {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("integration tests skipped: artifacts missing (run `make artifacts`)");
            None
        } else {
            // leak one runtime per test thread: cheap (a handful of
            // threads), keeps the compile cache warm across tests.
            match Runtime::new("artifacts") {
                Ok(rt) => Some(&*Box::leak(Box::new(rt))),
                Err(e) => {
                    eprintln!("integration tests skipped: runtime unavailable ({e})");
                    None
                }
            }
        }
    };
}

fn runtime() -> Option<&'static Runtime> {
    RT.with(|rt| *rt)
}

fn experiment(rt: &Runtime) -> Experiment<'_> {
    let xcfg = ExperimentConfig {
        calib_rows: 32, // keep integration tests fast
        eval_per_task: 8,
        train_steps: 2,
        ..ExperimentConfig::default()
    };
    Experiment::new(rt, xcfg)
}

fn init_params(rt: &Runtime) -> ParamStore {
    let cfg = ModelConfig::from_manifest(&rt.manifest().model_config);
    ParamStore::load(&cfg, "artifacts/init.rtz").expect("init.rtz")
}

#[test]
fn covariance_kernel_matches_rust_gram() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest().entry("covariance_d").unwrap().clone();
    let shape = spec.args[0].shape.clone();
    let mut rng = Rng::new(0);
    let n: usize = shape.iter().product();
    let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let y = Tensor::from_f32(&shape, data.clone());
    let out = rt.execute("covariance_d", &[&y]).unwrap();

    let d = shape[1];
    let mut acc = llm_rom::rom::CovarianceAccumulator::new(d);
    acc.update_rows(&data, shape[0], None).unwrap();
    let want = acc.finalize(false);
    let got = out[0].as_f32().unwrap();
    let mut max_err = 0.0f64;
    for i in 0..d {
        for j in 0..d {
            max_err = max_err.max((got[i * d + j] as f64 - want[(i, j)]).abs());
        }
    }
    assert!(max_err < 0.05, "pallas vs rust gram: max err {max_err}");
}

#[test]
fn lowrank_kernel_matches_rust() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest().entry("lowrank_attn_b46").unwrap().clone();
    let mut rng = Rng::new(1);
    let mk = |shape: &[usize], rng: &mut Rng| {
        let n: usize = shape.iter().product();
        Tensor::from_f32(shape, (0..n).map(|_| rng.normal() as f32 * 0.3).collect())
    };
    let x = mk(&spec.args[0].shape, &mut rng);
    let w2 = mk(&spec.args[1].shape, &mut rng);
    let w1 = mk(&spec.args[2].shape, &mut rng);
    let out = rt.execute("lowrank_attn_b46", &[&x, &w2, &w1]).unwrap();

    let (n, d1) = (spec.args[0].shape[0], spec.args[0].shape[1]);
    let (r, d2) = (spec.args[1].shape[0], spec.args[2].shape[0]);
    let t = llm_rom::linalg::matmul_transb_f32(x.as_f32().unwrap(), w2.as_f32().unwrap(), n, d1, r);
    let want = llm_rom::linalg::matmul_transb_f32(&t, w1.as_f32().unwrap(), n, r, d2);
    let got = out[0].as_f32().unwrap();
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() < 2e-2, "{g} vs {w}");
    }
}

#[test]
fn block_capture_consistent_with_block_fwd() {
    let Some(rt) = runtime() else { return };
    let params = init_params(rt);
    let cfg = ModelConfig::from_manifest(&rt.manifest().model_config);
    let (eb, es, d) = (cfg.eval_batch, cfg.eval_seq, cfg.d_model);
    let mut rng = Rng::new(2);
    let h = Tensor::from_f32(&[eb, es, d], (0..eb * es * d).map(|_| rng.normal() as f32 * 0.1).collect());

    let mut args = params.block_flat(0);
    args.push(&h);
    let cap = rt.execute("block_capture", &args).unwrap();
    let fwd = rt.execute("block_fwd", &args).unwrap();
    let a = cap[0].as_f32().unwrap();
    let b = fwd[0].as_f32().unwrap();
    for (x, y) in a.iter().zip(b) {
        assert!((x - y).abs() < 1e-4);
    }
    // y_q capture equals x_attn @ wq^T computed in rust
    let names = &rt.manifest().capture_names;
    let ix = |n: &str| names.iter().position(|c| c == n).unwrap() + 1;
    let x_attn = cap[ix("x_attn")].as_f32().unwrap();
    let y_q = cap[ix("y_q")].as_f32().unwrap();
    let wq = params.get("blocks.0.wq").unwrap().as_f32().unwrap();
    let want = llm_rom::linalg::matmul_transb_f32(x_attn, wq, eb * es, d, d);
    for (g, w) in y_q.iter().zip(&want) {
        assert!((g - w).abs() < 2e-3, "{g} vs {w}");
    }
}

#[test]
fn budget_one_preserves_scores_for_every_method() {
    // budget 1.0 means "compress nothing": the session short-circuits to
    // the identity artifact for every registered method, so task scores
    // are bit-identical.
    let Some(rt) = runtime() else { return };
    let exp = experiment(rt);
    let params = init_params(rt);
    let session = exp.session();

    let evaluator = Evaluator::new(rt);
    let task = Task::new(&exp.world, TaskKind::BoolLike);
    let insts = task.generate(Split::Eval, 8, 3);
    let s_before = evaluator.score_instances(&params, &insts).unwrap();
    for method in METHODS {
        let mut calib = EmptyStream;
        let cm = session.compress_at(method, &params, 1.0, &mut calib).unwrap();
        assert!(cm.accounting.layers.is_empty(), "{method}");
        let s_after = evaluator.score_instances(&cm.params, &insts).unwrap();
        for (a, b) in s_before.iter().flatten().zip(s_after.iter().flatten()) {
            assert!((a - b).abs() < 1e-9, "{method}: {a} vs {b}");
        }
    }
}

#[test]
fn rom_factors_structurally_sound_through_real_pipeline() {
    // end-to-end invariant of the capture → covariance → eigh →
    // re-parameterize path (not the budget-1.0 short-circuit): every
    // factor's V has orthonormal rows (W1ᵀW1 = I_r) and W2 = V·W, so
    // W_eff = VᵀV·W — the projector structure the paper's §2 promises.
    let Some(rt) = runtime() else { return };
    let exp = experiment(rt);
    let params = init_params(rt);
    let calib = exp.calibration(32, exp.cfg.eval_seq, CalibSource::Combination);
    let pipeline = RomPipeline::new(rt);
    let last = exp.cfg.n_layers - 1;
    let rcfg = RomConfig {
        schedule: ModuleSchedule { start_block: last, module_budget: 0.46 },
        ..RomConfig::default()
    };
    let rom = pipeline.compress(&params, &calib, &rcfg).unwrap();
    assert_eq!(rom.factors.len(), 7);
    for (name, f) in &rom.factors {
        // W1 = Vᵀ (d2 × r): Vᵀ's gram must be the identity
        let gram = llm_rom::linalg::matmul(&f.w1.transpose(), &f.w1);
        for i in 0..f.rank {
            for j in 0..f.rank {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (gram[(i, j)] - want).abs() < 1e-8,
                    "{name}: VVᵀ[{i},{j}] = {}",
                    gram[(i, j)]
                );
            }
        }
        // W2 = V·W for the original weight
        let w = params.get(name).unwrap().to_matrix().unwrap();
        let vw = llm_rom::linalg::matmul(&f.w1.transpose(), &w);
        assert!(vw.sub(&f.w2).max_abs() < 1e-8, "{name}: W2 != V·W");
        assert!(f.energy > 0.0 && f.energy <= 1.0 + 1e-12, "{name}: energy {}", f.energy);
    }
}

#[test]
fn all_methods_run_through_registry_at_80pct() {
    // the acceptance path: every registered method produces a
    // CompressedModel through the one trait pipeline, with accounting
    // strictly below dense and provenance recording the method.
    let Some(rt) = runtime() else { return };
    let exp = experiment(rt);
    let params = init_params(rt);
    let dense = macs::report(&exp.cfg, &macs::CompressionAccounting::dense(), 64);
    for method in METHODS {
        let cm = exp.compress_method(&params, method, 0.8).unwrap();
        assert_eq!(cm.provenance.method, *method);
        assert!((cm.provenance.global_budget - 0.8).abs() < 1e-12);
        let rep = cm.macs_report(&exp.cfg, 64);
        assert!(rep.n_params < dense.n_params, "{method}: {} params", rep.n_params);
        assert!(!cm.timings.is_empty(), "{method} recorded no timings");
        if method.starts_with("prune") {
            assert!(cm.masks.is_some(), "{method} should carry masks");
        } else {
            assert!(cm.masks.is_none(), "{method} should not carry masks");
        }
    }
}

#[test]
fn compressed_model_rtz_roundtrip_with_runtime() {
    let Some(rt) = runtime() else { return };
    let exp = experiment(rt);
    let params = init_params(rt);
    let cm = exp.compress_method(&params, "rom-feature", 0.8).unwrap();
    let dir = std::env::temp_dir().join(format!("cm_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("rom.rtz");
    cm.save(&path).unwrap();
    let back = llm_rom::compress::CompressedModel::load(&exp.cfg, &path).unwrap();
    assert!(back.params.distance(&cm.params).unwrap() < 1e-12);
    assert_eq!(back.accounting.layers, cm.accounting.layers);
    assert_eq!(back.provenance, cm.provenance);
    // a compressed artifact still loads as a plain checkpoint
    let plain = ParamStore::load(&exp.cfg, &path).unwrap();
    assert!(plain.distance(&cm.params).unwrap() < 1e-12);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn offline_session_matches_runtime_session_on_data_free_method() {
    // rom-weight-svd is data-free: an offline CompressionSession (no
    // PJRT) must produce the same artifact as the runtime-backed one.
    let Some(rt) = runtime() else { return };
    let exp = experiment(rt);
    let params = init_params(rt);
    let online = exp.compress_method(&params, "rom-weight-svd", 0.8).unwrap();
    let offline_session = CompressionSession::offline(exp.cfg.clone());
    let mut calib = EmptyStream;
    let offline = offline_session
        .compress_at("rom-weight-svd", &params, 0.8, &mut calib)
        .unwrap();
    assert!(online.params.distance(&offline.params).unwrap() < 1e-9);
    assert_eq!(online.accounting.layers, offline.accounting.layers);
}

#[test]
fn rom_respects_budget_accounting() {
    let Some(rt) = runtime() else { return };
    let exp = experiment(rt);
    let params = init_params(rt);
    let calib = exp.calibration(32, exp.cfg.eval_seq, CalibSource::Combination);
    let pipeline = RomPipeline::new(rt);
    let sched = llm_rom::rom::paper_preset(&exp.cfg, 0.8);
    let rcfg = RomConfig { schedule: sched, ..RomConfig::default() };
    let rom = pipeline.compress(&params, &calib, &rcfg).unwrap();

    assert_eq!(rom.factors.len(), 7 * sched.n_compressed(&exp.cfg));
    let rep = macs::report(&exp.cfg, &rom.accounting(), 64);
    let dense = macs::report(&exp.cfg, &macs::CompressionAccounting::dense(), 64);
    let achieved = rep.n_params as f64 / dense.n_params as f64;
    assert!((achieved - 0.8).abs() < 0.02, "achieved {achieved}");
    assert!(rep.macs < dense.macs);
    // timings recorded per matrix
    assert_eq!(rom.timings.len(), rom.factors.len());
    assert!(rom.total_rom_seconds() > 0.0);
}

#[test]
fn rom_pallas_and_rust_covariance_agree() {
    let Some(rt) = runtime() else { return };
    let exp = experiment(rt);
    let params = init_params(rt);
    let calib = exp.calibration(32, exp.cfg.eval_seq, CalibSource::Combination);
    let pipeline = RomPipeline::new(rt);
    let last = exp.cfg.n_layers - 1;
    let mk = |pallas| RomConfig {
        schedule: ModuleSchedule { start_block: last, module_budget: 0.46 },
        pallas_covariance: pallas,
        ..RomConfig::default()
    };
    let a = pipeline.compress(&params, &calib, &mk(true)).unwrap();
    let b = pipeline.compress(&params, &calib, &mk(false)).unwrap();
    // same subspaces -> same effective weights (up to f32/f64 path noise)
    for (name, fa) in &a.factors {
        let fb = &b.factors[name];
        assert_eq!(fa.rank, fb.rank);
        let diff = fa.effective_weight().sub(&fb.effective_weight()).max_abs();
        assert!(diff < 1e-3, "{name}: {diff}");
    }
}

#[test]
fn padded_calibration_rows_do_not_change_result() {
    // same 32 real rows, once tight and once with extra all-PAD rows in
    // the batch -> identical factors (padding exclusion works end-to-end)
    let Some(rt) = runtime() else { return };
    let exp = experiment(rt);
    let params = init_params(rt);
    let pipeline = RomPipeline::new(rt);
    let last = exp.cfg.n_layers - 1;
    let rcfg = RomConfig {
        schedule: ModuleSchedule { start_block: last, module_budget: 0.46 },
        ..RomConfig::default()
    };
    let calib = exp.calibration(32, exp.cfg.eval_seq, CalibSource::Combination);
    assert_eq!(calib.len(), 1);
    let a = pipeline.compress(&params, &calib, &rcfg).unwrap();

    // clone the batch, then blank the last 8 rows (valid=0)
    let mut cal2 = calib.clone();
    let es = exp.cfg.eval_seq;
    for row in 24..32 {
        cal2[0].valid[row] = 0;
        for t in 0..es {
            cal2[0].tokens[row * es + t] = llm_rom::data::PAD;
        }
    }
    // and a reference with only the 24 real rows
    let mut cal3 = calib.clone();
    for row in 24..32 {
        cal3[0].valid[row] = 0;
        for t in 0..es {
            cal3[0].tokens[row * es + t] = llm_rom::data::PAD;
        }
    }
    let b = pipeline.compress(&params, &cal2, &rcfg).unwrap();
    let c = pipeline.compress(&params, &cal3, &rcfg).unwrap();
    for (name, fb) in &b.factors {
        let fc = &c.factors[name];
        let diff = fb.effective_weight().sub(&fc.effective_weight()).max_abs();
        assert!(diff < 1e-6, "{name}: {diff}");
        // and differs from the full-32-row run (sanity that masking did
        // something at all)
        let _ = &a;
    }
}

#[test]
fn weight_space_ablation_needs_no_calibration() {
    let Some(rt) = runtime() else { return };
    let exp = experiment(rt);
    let params = init_params(rt);
    let pipeline = RomPipeline::new(rt);
    let last = exp.cfg.n_layers - 1;
    let rcfg = RomConfig {
        schedule: ModuleSchedule { start_block: last, module_budget: 0.46 },
        space: llm_rom::rom::DecompositionSpace::Weight,
        ..RomConfig::default()
    };
    // empty calibration is fine in weight space
    let rom = pipeline.compress(&params, &[], &rcfg).unwrap();
    assert_eq!(rom.factors.len(), 7);
    // and it must differ from the feature-space result
    let calib = exp.calibration(32, exp.cfg.eval_seq, CalibSource::Combination);
    let feat = pipeline
        .compress(
            &params,
            &calib,
            &RomConfig {
                schedule: ModuleSchedule { start_block: last, module_budget: 0.46 },
                ..RomConfig::default()
            },
        )
        .unwrap();
    let mut any_diff = false;
    for (name, fw) in &rom.factors {
        let ff = &feat.factors[name];
        if fw.effective_weight().sub(&ff.effective_weight()).max_abs() > 1e-4 {
            any_diff = true;
        }
    }
    assert!(any_diff, "weight-space and feature-space gave identical factors");
}

#[test]
fn no_propagation_ablation_differs_when_multiple_modules() {
    let Some(rt) = runtime() else { return };
    let exp = experiment(rt);
    let params = init_params(rt);
    let calib = exp.calibration(32, exp.cfg.eval_seq, CalibSource::Combination);
    let pipeline = RomPipeline::new(rt);
    // compress the last two modules hard so propagation matters
    let sched = ModuleSchedule { start_block: exp.cfg.n_layers - 2, module_budget: 0.33 };
    let with = pipeline
        .compress(&params, &calib, &RomConfig { schedule: sched, ..RomConfig::default() })
        .unwrap();
    let without = pipeline
        .compress(
            &params,
            &calib,
            &RomConfig { schedule: sched, propagate_errors: false, ..RomConfig::default() },
        )
        .unwrap();
    // first compressed module's qkv see identical inputs -> similar; the
    // SECOND module must differ (its calibration stream diverged)
    let second = format!("blocks.{}.wq", exp.cfg.n_layers - 1);
    let diff = with.factors[&second]
        .effective_weight()
        .sub(&without.factors[&second].effective_weight())
        .max_abs();
    assert!(diff > 1e-6, "propagation had no effect on downstream module ({diff})");
    // same ranks either way
    for (name, f) in &with.factors {
        assert_eq!(f.rank, without.factors[name].rank);
    }
}

#[test]
fn pruning_masks_zero_rows_and_accounting_matches() {
    let Some(rt) = runtime() else { return };
    let exp = experiment(rt);
    let params = init_params(rt);
    let calib = exp.calibration(32, exp.cfg.eval_seq, CalibSource::Combination);
    let sched = llm_rom::rom::paper_preset(&exp.cfg, 0.8);
    let pruned = Pruner::new(rt).prune(&params, &calib, sched, Importance::ActivationAware).unwrap();

    let cfg = &exp.cfg;
    for (&block, kept) in &pruned.kept_ffn {
        assert_eq!(kept.len(), (cfg.d_ff as f64 * sched.module_budget).round() as usize);
        // pruned rows of w_gate are zero
        let gate = pruned.params.get(&format!("blocks.{block}.w_gate")).unwrap().as_f32().unwrap();
        for c in 0..cfg.d_ff {
            let row = &gate[c * cfg.d_model..(c + 1) * cfg.d_model];
            let zero = row.iter().all(|&x| x == 0.0);
            assert_eq!(zero, !kept.contains(&c), "block {block} channel {c}");
        }
    }
    // masks multiply params to themselves (masks consistent with zeros)
    let maskable = &rt.manifest().maskable_names;
    for (name, mask) in maskable.iter().zip(&pruned.masks) {
        let w = pruned.params.get(name).unwrap().as_f32().unwrap();
        let m = mask.as_f32().unwrap();
        for (x, k) in w.iter().zip(m) {
            assert!((x * k - x).abs() < 1e-12, "{name}");
        }
    }
    // params accounting strictly below dense
    let rep = macs::report(cfg, &pruned.accounting(cfg), 64);
    assert!(rep.n_params < cfg.n_params());
}

#[test]
fn magnitude_and_wanda_pruning_differ() {
    let Some(rt) = runtime() else { return };
    let exp = experiment(rt);
    // train a couple of steps so activations are not isotropic
    let params = init_params(rt);
    let calib = exp.calibration(32, exp.cfg.eval_seq, CalibSource::Combination);
    let sched = llm_rom::rom::paper_preset(&exp.cfg, 0.8);
    let p = Pruner::new(rt);
    let a = p.prune(&params, &calib, sched, Importance::Magnitude).unwrap();
    let b = p.prune(&params, &calib, sched, Importance::ActivationAware).unwrap();
    // at least one block should keep a different channel set
    let differs = a
        .kept_ffn
        .iter()
        .any(|(blk, kept)| b.kept_ffn.get(blk).map(|k2| k2 != kept).unwrap_or(true));
    assert!(differs, "importance criteria produced identical prunings");
}

#[test]
fn train_step_decreases_loss_via_runtime() {
    let Some(rt) = runtime() else { return };
    let exp = experiment(rt);
    let init = init_params(rt);
    let corpus = exp.corpus();
    let batches = llm_rom::data::pack_lm_batches(
        &corpus,
        exp.cfg.train_batch,
        exp.cfg.train_seq,
        6,
        7,
    );
    let mut trainer = llm_rom::train::Trainer::new(rt, init);
    let mut losses = Vec::new();
    for b in &batches {
        losses.push(trainer.step(b, 2e-3).unwrap());
    }
    assert!(losses.last().unwrap() < losses.first().unwrap(), "{losses:?}");
}

#[test]
fn masked_finetune_preserves_pruned_zeros_via_runtime() {
    let Some(rt) = runtime() else { return };
    let exp = experiment(rt);
    let params = init_params(rt);
    let pruned = exp.compress_method(&params, "prune-magnitude", 0.8).unwrap();
    let masks = pruned.masks.as_ref().expect("pruned artifact carries masks");
    let ft = exp.finetune_compressed(&pruned, 2, |_, _, _| {}).unwrap();
    // zeros stayed zero
    let maskable = &rt.manifest().maskable_names;
    for (name, mask) in maskable.iter().zip(masks) {
        let w = ft.get(name).unwrap().as_f32().unwrap();
        let m = mask.as_f32().unwrap();
        for (x, k) in w.iter().zip(m) {
            if *k == 0.0 {
                assert_eq!(*x, 0.0, "{name}");
            }
        }
    }
    // and the model actually changed where unmasked
    assert!(ft.distance(&pruned.params).unwrap() > 1e-3);
}

#[test]
fn reference_model_matches_hlo_forward() {
    // End-to-end numerics: the pure-Rust reference model and the AOT HLO
    // graph (Pallas attention + RMSNorm inside) must agree on logits.
    let Some(rt) = runtime() else { return };
    let params = init_params(rt);
    let cfg = ModelConfig::from_manifest(&rt.manifest().model_config);
    let (eb, es) = (cfg.eval_batch, cfg.eval_seq);

    let seq: Vec<i32> = (0..es as i32).map(|t| (t * 7 + 3) % 250).collect();
    let mut batch = vec![llm_rom::data::PAD; eb * es];
    batch[..es].copy_from_slice(&seq);
    let tokens = Tensor::from_i32(&[eb, es], batch);
    let mut args: Vec<&Tensor> = params.flat();
    args.push(&tokens);
    let outs = rt.execute("forward_logits", &args).unwrap();
    let hlo_logits = outs[0].as_f32().unwrap();

    let reference = llm_rom::model::ReferenceModel::new(&params);
    let ref_logits = reference.forward_logits(&seq).unwrap();

    // compare row 0 of the batch across all positions/vocab
    let v = cfg.vocab;
    let mut max_err = 0.0f32;
    for t in 0..es {
        for j in 0..v {
            let a = hlo_logits[t * v + j];
            let b = ref_logits[t * v + j];
            max_err = max_err.max((a - b).abs());
        }
    }
    // two independent f32 implementations with different accumulation
    // orders drift ~1e-2 on logits after 8 residual blocks; 5e-2 still
    // catches any real wiring/marshalling bug (those produce O(1) errors)
    assert!(max_err < 5e-2, "reference vs HLO logits: max err {max_err}");
}

#[test]
fn evaluator_scores_are_finite_and_ordered() {
    let Some(rt) = runtime() else { return };
    let exp = experiment(rt);
    let params = init_params(rt);
    let evaluator = Evaluator::new(rt);
    for kind in [TaskKind::BoolLike, TaskKind::QaEasy] {
        let task = Task::new(&exp.world, kind);
        let insts = task.generate(Split::Eval, 8, 11);
        let scores = evaluator.score_instances(&params, &insts).unwrap();
        for row in &scores {
            assert_eq!(row.len(), kind.n_choices());
            for s in row {
                assert!(s.is_finite(), "score {s}");
                assert!(*s <= 0.0, "logprob must be ≤ 0, got {s}");
            }
        }
    }
}

#[test]
fn perplexity_is_reasonable_for_untrained_model() {
    let Some(rt) = runtime() else { return };
    let exp = experiment(rt);
    let params = init_params(rt);
    let evaluator = Evaluator::new(rt);
    let ppl = evaluator.perplexity(&params, &exp.ppl_text()).unwrap();
    // untrained byte-level model: ppl near uniform over ~260 used ids,
    // definitely within (1, vocab]
    assert!(ppl > 1.0 && ppl <= 320.0 * 2.0, "ppl {ppl}");
}
