//! Property-based tests over coordinator/ROM invariants.
//!
//! The offline build carries no proptest crate, so properties are driven
//! by the in-crate deterministic RNG: each property runs across a sweep of
//! generated cases with shrink-free but reproducible seeds (failure
//! messages include the case seed).

use llm_rom::compress::{resolve, CompressedModel, CompressionSession, EmptyStream, METHODS};
use llm_rom::linalg::{eigh, eigh_jacobi, matmul, Matrix};
use llm_rom::model::{param_shape, ModelConfig, ParamStore};
use llm_rom::rom::budget::{candidates, rank_for_budget, solve_module_budget, ModuleSchedule};
use llm_rom::rom::decompose::{factors_from_eigen, rank_for_energy};
use llm_rom::rom::CovarianceAccumulator;
use llm_rom::tensor::Tensor;
use llm_rom::util::json::Json;
use llm_rom::util::Rng;

const CASES: u64 = 40;

/// Tiny schema for offline compression properties (runtime-free).
fn tiny_cfg() -> ModelConfig {
    ModelConfig { vocab: 16, d_model: 8, n_heads: 2, n_layers: 2, d_ff: 12, ..ModelConfig::mini() }
}

/// A ParamStore filled with seeded gaussian values.
fn random_params(cfg: &ModelConfig, seed: u64) -> ParamStore {
    let mut p = ParamStore::zeros(cfg);
    let mut rng = Rng::new(seed);
    for name in p.names().to_vec() {
        let shape = param_shape(cfg, &name);
        let n: usize = shape.iter().product();
        let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
        p.set(&name, Tensor::from_f32(&shape, data)).unwrap();
    }
    p
}

/// Property: eigh residuals, orthonormality, and agreement with Jacobi on
/// arbitrary symmetric matrices.
#[test]
fn prop_eigh_correct_on_random_symmetric() {
    for case in 0..CASES {
        let mut rng = Rng::new(case * 7919 + 1);
        let n = 1 + rng.below(40);
        let scale = 10f64.powi(rng.below(5) as i32 - 2);
        let mut a = Matrix::from_fn(n, n, |_, _| rng.normal() * scale);
        a.symmetrize();
        let dec = eigh(&a).unwrap_or_else(|e| panic!("case {case} (n={n}): {e}"));
        // residuals
        for k in 0..n {
            let v = dec.vectors.row(k).to_vec();
            let av = a.matvec(&v);
            for i in 0..n {
                let r = (av[i] - dec.values[k] * v[i]).abs();
                assert!(r < 1e-7 * (1.0 + a.max_abs()), "case {case} pair {k}: residual {r}");
            }
        }
        // eigenvalues agree with jacobi
        let jd = eigh_jacobi(&a).unwrap();
        for (x, y) in dec.values.iter().zip(&jd.values) {
            assert!((x - y).abs() < 1e-6 * (1.0 + a.max_abs()), "case {case}: {x} vs {y}");
        }
    }
}

/// Property: ROM reconstruction error is monotone non-increasing in rank
/// and exactly zero at full rank, for any data distribution.
#[test]
fn prop_rom_error_monotone_in_rank() {
    for case in 0..CASES {
        let mut rng = Rng::new(case * 104729 + 3);
        let d1 = 2 + rng.below(12);
        let d2 = 2 + rng.below(12);
        let n = d2 + 4 + rng.below(50);
        let w = Matrix::from_fn(d2, d1, |_, _| rng.normal());
        let x = Matrix::from_fn(n, d1, |_, _| rng.normal());
        let y = matmul(&x, &w.transpose());
        let cov = matmul(&y.transpose(), &y);
        let dec = eigh(&cov).unwrap();
        let mut prev = f64::INFINITY;
        for rank in 1..=d2 {
            let f = factors_from_eigen(&w, &dec, rank);
            let err = matmul(&x, &f.effective_weight().transpose()).sub(&y).frobenius_norm();
            assert!(err <= prev + 1e-7, "case {case} rank {rank}: {err} > {prev}");
            prev = err;
        }
        assert!(prev < 1e-6 * (1.0 + y.frobenius_norm()), "case {case}: full rank err {prev}");
    }
}

/// Property: energy-based rank is the minimal rank reaching the threshold.
#[test]
fn prop_energy_rank_minimal() {
    for case in 0..CASES {
        let mut rng = Rng::new(case * 31337 + 5);
        let d = 2 + rng.below(20);
        let n = d + rng.below(40);
        let y = Matrix::from_fn(n, d, |_, _| rng.normal());
        let cov = matmul(&y.transpose(), &y);
        let dec = eigh(&cov).unwrap();
        let energy = 0.5 + rng.f64() * 0.45;
        let r = rank_for_energy(&dec, energy);
        let total: f64 = dec.values.iter().map(|l| l.max(0.0)).sum();
        let mass = |k: usize| dec.values.iter().take(k).map(|l| l.max(0.0)).sum::<f64>() / total;
        assert!(mass(r) >= energy - 1e-12, "case {case}");
        if r > 1 {
            assert!(mass(r - 1) < energy, "case {case}: rank not minimal");
        }
    }
}

/// Property: covariance accumulation is chunking-invariant (any split of
/// the rows gives the same matrix) and sample counts add up.
#[test]
fn prop_covariance_chunking_invariant() {
    for case in 0..CASES {
        let mut rng = Rng::new(case * 6151 + 7);
        let d = 1 + rng.below(16);
        let n = 4 + rng.below(120);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let mut whole = CovarianceAccumulator::new(d);
        whole.update_rows(&rows, n, None).unwrap();

        let mut split = CovarianceAccumulator::new(d);
        let mut at = 0;
        while at < n {
            let take = 1 + rng.below(n - at);
            split.update_rows(&rows[at * d..(at + take) * d], take, None).unwrap();
            at += take;
        }
        assert_eq!(whole.samples(), split.samples());
        let diff = whole.finalize(false).sub(&split.finalize(false)).max_abs();
        assert!(diff < 1e-8, "case {case}: {diff}");
    }
}

/// Property: the budget solver inverts the schedule's achieved budget for
/// every feasible (k, global) pair, and ranks never exceed dims.
#[test]
fn prop_budget_solver_inverts() {
    let cfgs = [ModelConfig::mini(), ModelConfig::llama7b()];
    for (ci, cfg) in cfgs.iter().enumerate() {
        for case in 0..CASES {
            let mut rng = Rng::new(case * 911 + ci as u64);
            let global = 0.3 + rng.f64() * 0.69;
            let k = 1 + rng.below(cfg.n_layers);
            if let Some(b) = solve_module_budget(cfg, k, global) {
                let s = ModuleSchedule { start_block: cfg.n_layers - k, module_budget: b };
                let achieved = s.global_budget(cfg);
                assert!(
                    (achieved - global).abs() < 0.02,
                    "cfg {ci} case {case}: k={k} g={global} achieved={achieved}"
                );
                for (_, o, i) in llm_rom::model::macs::block_matrices(cfg, cfg.n_layers - 1) {
                    let r = rank_for_budget(o, i, b);
                    assert!(r >= 1 && r <= o.min(i));
                }
            }
        }
    }
}

/// Property: every candidate schedule for a budget actually achieves it.
#[test]
fn prop_candidates_all_feasible() {
    let cfg = ModelConfig::mini();
    for case in 0..CASES {
        let mut rng = Rng::new(case * 503 + 11);
        let global = 0.35 + rng.f64() * 0.6;
        for s in candidates(&cfg, global) {
            let achieved = s.global_budget(&cfg);
            assert!((achieved - global).abs() < 0.02, "case {case}: {achieved} vs {global}");
            assert!(s.module_budget > 0.0 && s.module_budget <= 1.0);
        }
    }
}

/// Property: JSON display/parse round-trips arbitrary JSON-shaped trees.
#[test]
fn prop_json_roundtrip() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.normal() * 1e3).round() / 8.0),
            3 => {
                let n = rng.below(8);
                Json::Str((0..n).map(|_| *rng.choose(&['a', 'é', '"', '\\', '\n', 'z', '😀', ' '])).collect())
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for case in 0..200 {
        let mut rng = Rng::new(case * 2221 + 13);
        let v = gen(&mut rng, 3);
        let text = v.to_string();
        let v2 = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(v, v2, "case {case}: {text}");
    }
}

/// Property: every registered `Compressor` at budget 1.0 is a
/// near-identity on params (exactly identity: budget 1.0 means "compress
/// nothing", which needs neither runtime nor calibration data).
#[test]
fn prop_every_compressor_identity_at_budget_one() {
    let cfg = tiny_cfg();
    let session = CompressionSession::offline(cfg.clone());
    for case in 0..8u64 {
        let params = random_params(&cfg, case * 271 + 19);
        for method in METHODS {
            let mut calib = EmptyStream;
            let cm = session.compress_at(method, &params, 1.0, &mut calib).unwrap();
            let d = cm.params.distance(&params).unwrap();
            assert!(d < 1e-12, "case {case} {method}: distance {d}");
            assert!(cm.accounting.layers.is_empty(), "case {case} {method}");
            assert_eq!(cm.provenance.method, *method);
        }
    }
}

/// Property: registry names resolve to compressors reporting the same
/// name; unknown names are rejected.
#[test]
fn prop_registry_names_are_canonical() {
    for name in METHODS {
        assert_eq!(resolve(name).unwrap().name(), *name);
    }
    for bogus in ["", "rom", "ROM-FEATURE", "prune", "magnitude"] {
        assert!(resolve(bogus).is_err(), "`{bogus}` should not resolve");
    }
}

/// Property: `CompressedModel` round-trips through `.rtz` — params,
/// accounting, and provenance all survive — across random budgets, for
/// both data-free method families.
#[test]
fn prop_compressed_model_rtz_roundtrip() {
    let cfg = tiny_cfg();
    let session = CompressionSession::offline(cfg.clone());
    let dir = std::env::temp_dir().join(format!("cm_prop_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..10u64 {
        let mut rng = Rng::new(case * 7001 + 23);
        let params = random_params(&cfg, case * 733 + 5);
        let budget = 0.45 + rng.f64() * 0.5;
        for method in ["rom-weight-svd", "prune-magnitude"] {
            let mut calib = EmptyStream;
            let cm = session.compress_at(method, &params, budget, &mut calib).unwrap();
            let path = dir.join(format!("{method}_{case}.rtz"));
            cm.save(&path).unwrap();
            let back = CompressedModel::load(&cfg, &path).unwrap();
            let d = back.params.distance(&cm.params).unwrap();
            assert!(d < 1e-12, "case {case} {method}: params distance {d}");
            assert_eq!(back.accounting.layers, cm.accounting.layers, "case {case} {method}");
            // ROM factors ride along bit-exactly (empty for pruning)
            assert_eq!(back.factors.len(), cm.factors.len(), "case {case} {method}");
            for (name, f) in &cm.factors {
                let g = &back.factors[name];
                assert_eq!(g.rank, f.rank, "case {case} {name}");
                assert_eq!(g.energy, f.energy, "case {case} {name}");
                assert_eq!(g.w1.data(), f.w1.data(), "case {case} {name}: w1 not lossless");
                assert_eq!(g.w2.data(), f.w2.data(), "case {case} {name}: w2 not lossless");
            }
            assert_eq!(back.provenance, cm.provenance, "case {case} {method}");
            assert_eq!(back.timings.len(), cm.timings.len(), "case {case} {method}");
            assert_eq!(back.peak_capture_bytes, cm.peak_capture_bytes);
            // pruned artifacts round-trip their kept sets and rebuild
            // identical masks, so masked fine-tune works after load
            assert_eq!(back.kept, cm.kept, "case {case} {method}");
            match (&cm.masks, &back.masks) {
                (None, None) => {}
                (Some(a), Some(b)) => assert_eq!(a, b, "case {case} {method}: masks differ"),
                _ => panic!("case {case} {method}: masks presence changed across round-trip"),
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Property: offline sessions run data-free methods below budget 1.0 and
/// reject activation-capturing ones with a clear error.
#[test]
fn prop_offline_session_capability_split() {
    let cfg = tiny_cfg();
    let session = CompressionSession::offline(cfg.clone());
    let params = random_params(&cfg, 99);
    for method in ["rom-weight-svd", "prune-magnitude"] {
        let mut calib = EmptyStream;
        let cm = session.compress_at(method, &params, 0.8, &mut calib).unwrap();
        assert!(!cm.accounting.layers.is_empty(), "{method} compressed nothing");
    }
    for method in ["rom-feature", "prune-activation"] {
        let mut calib = EmptyStream;
        let err = session.compress_at(method, &params, 0.8, &mut calib).unwrap_err();
        assert!(err.to_string().contains("runtime"), "{method}: {err}");
    }
}

/// Property: `rank_for_budget` is monotone non-decreasing in the budget
/// and always within [1, min(d_out, d_in)].
#[test]
fn prop_rank_for_budget_monotone() {
    for case in 0..CASES {
        let mut rng = Rng::new(case * 40487 + 29);
        let d_out = 2 + rng.below(300);
        let d_in = 2 + rng.below(300);
        let mut prev = 0usize;
        for step in 1..=40 {
            let b = step as f64 / 40.0;
            let r = rank_for_budget(d_out, d_in, b);
            assert!(r >= 1 && r <= d_out.min(d_in), "case {case} b={b}: rank {r}");
            assert!(r >= prev, "case {case} b={b}: rank {r} < previous {prev}");
            prev = r;
        }
    }
}

/// Property: across random budgets and seeds, factored-form serving
/// matches the re-densified path to ≤1e-4 on logits, and the MACs it
/// executes equal the artifact's analytic accounting (never more than the
/// dense path's).
#[test]
fn prop_factored_serving_matches_dense() {
    use llm_rom::serve::{demo_artifact, demo_config, synth_requests, ExecMode, ServeModel};
    let cfg = demo_config();
    for case in 0..8u64 {
        let mut rng = Rng::new(case * 6007 + 37);
        let budget = 0.4 + rng.f64() * 0.5;
        let cm = demo_artifact(&cfg, budget, case).unwrap();
        let dense = ServeModel::from_artifact(&cm, ExecMode::Dense).unwrap();
        let fact = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
        assert_eq!(fact.n_factored(), cm.factors.len(), "case {case}");
        for req in synth_requests(&cfg, 2, 8 + rng.below(16), case * 13 + 1) {
            let (ld, md) = dense.forward_logits(&req.tokens).unwrap();
            let (lf, mf) = fact.forward_logits(&req.tokens).unwrap();
            let diff =
                ld.iter().zip(&lf).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
            assert!(diff <= 1e-4, "case {case} b={budget:.2}: max |Δlogits| = {diff}");
            let t = req.tokens.len();
            let want = llm_rom::model::macs::report(&cfg, &cm.accounting, t).macs;
            assert_eq!(mf, want, "case {case}: served MACs != accounting MACs");
            assert!(mf <= md, "case {case}: factored executed more MACs than dense");
        }
    }
}

/// Property: the serving engine's batching/threading never changes
/// results — any (workers, max_batch) split serves the same logits and
/// the same total MACs as the sequential run.
#[test]
fn prop_serve_engine_schedule_invariant() {
    use llm_rom::serve::{
        demo_artifact, demo_config, synth_requests, ExecMode, ServeConfig, ServeEngine,
        ServeModel,
    };
    let cfg = demo_config();
    let cm = demo_artifact(&cfg, 0.5, 77).unwrap();
    let reqs = || synth_requests(&cfg, 7, 10, 5);
    let run = |workers: usize, max_batch: usize| {
        let model = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
        let engine =
            ServeEngine::new(model, ServeConfig { workers, max_batch, ..Default::default() });
        engine.run(reqs()).unwrap()
    };
    let (base, base_stats) = run(1, 1);
    for (w, b) in [(2, 1), (2, 3), (4, 2), (3, 100)] {
        let (results, stats) = run(w, b);
        assert_eq!(results.len(), base.len(), "{w}/{b}");
        for (x, y) in results.iter().zip(&base) {
            assert_eq!(x.id, y.id, "{w}/{b}");
            assert_eq!(x.logits, y.logits, "{w}/{b}: scheduling changed logits");
            assert_eq!(x.macs, y.macs, "{w}/{b}");
        }
        assert_eq!(stats.core.macs, base_stats.core.macs, "{w}/{b}");
        assert_eq!(stats.core.tokens, base_stats.core.tokens, "{w}/{b}");
    }
}

/// Property: for random small configs and budgets, KV-cached incremental
/// decode produces token streams identical to full-recompute greedy
/// decode — in both execution modes — and the MACs it executes equal the
/// analytic cached-decode accounting (`macs::decode_report`), which is
/// strictly below the recompute baseline.
#[test]
fn prop_kv_decode_matches_recompute_decode() {
    use llm_rom::decode::{
        run_recompute, synth_gen_requests, DecodeConfig, DecodeScheduler, Sampling,
    };
    use llm_rom::model::macs::{decode_report, CompressionAccounting};
    use llm_rom::serve::{demo_artifact, ExecMode, ServeModel};
    for case in 0..6u64 {
        let mut rng = Rng::new(case * 9973 + 41);
        let (d_model, n_heads) = *rng.choose(&[(16usize, 2usize), (24, 2), (32, 4)]);
        let cfg = ModelConfig {
            vocab: 40 + rng.below(40),
            d_model,
            n_heads,
            n_layers: 2 + rng.below(2),
            d_ff: d_model + rng.below(d_model),
            ..ModelConfig::mini()
        };
        let budget = 0.4 + rng.f64() * 0.5;
        let cm = demo_artifact(&cfg, budget, case * 7 + 1).unwrap();
        let prompt_len = 3 + rng.below(8);
        let max_new = 3 + rng.below(8);
        let config = DecodeConfig {
            slots: 1 + rng.below(3),
            capacity: prompt_len + max_new,
            max_new,
            sampling: Sampling::Greedy,
            seed: case,
            eos: None,
            ..DecodeConfig::default()
        };
        let reqs = synth_gen_requests(&cfg, 2 + rng.below(4), prompt_len, case * 13 + 3);
        for mode in [ExecMode::Dense, ExecMode::Factored] {
            let model = ServeModel::from_artifact(&cm, mode).unwrap();
            let acc = match mode {
                ExecMode::Dense => CompressionAccounting::dense(),
                ExecMode::Factored => cm.accounting.clone(),
            };
            let (kv, kv_stats) =
                DecodeScheduler::new(&model, config).run(reqs.clone()).unwrap();
            let (rc, rc_stats) = run_recompute(&model, &reqs, &config).unwrap();
            assert_eq!(kv.len(), rc.len(), "case {case} {mode:?}");
            for (a, b) in kv.iter().zip(&rc) {
                assert_eq!(a.id, b.id, "case {case} {mode:?}");
                assert_eq!(
                    a.tokens, b.tokens,
                    "case {case} {mode:?}: request {} stream diverged",
                    a.id
                );
                let rep = decode_report(&cfg, &acc, a.prompt_len, a.tokens.len());
                assert_eq!(
                    a.macs,
                    rep.cached_macs(),
                    "case {case} {mode:?}: executed != analytic (request {})",
                    a.id
                );
                assert_eq!(b.macs, rep.recompute_macs, "case {case} {mode:?}");
            }
            assert_eq!(kv_stats.recompute_macs, rc_stats.core.macs, "case {case} {mode:?}");
            assert!(
                kv_stats.core.macs < rc_stats.core.macs,
                "case {case} {mode:?}: the cache must save MACs"
            );
        }
    }
}

/// Property: scheduler admission is FIFO for any (requests, slots,
/// per-request budgets) mix — no request is overtaken or starved, every
/// request completes within its budget, and concurrency never exceeds the
/// slot count.
#[test]
fn prop_scheduler_admission_fifo_never_starves() {
    use llm_rom::decode::{DecodeConfig, DecodeScheduler, GenRequest, Sampling};
    use llm_rom::serve::{demo_artifact, demo_config, ExecMode, ServeModel};
    let cfg = demo_config();
    let cm = demo_artifact(&cfg, 0.5, 83).unwrap();
    let model = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
    for case in 0..8u64 {
        let mut rng = Rng::new(case * 6133 + 47);
        let n = 1 + rng.below(10);
        let slots = 1 + rng.below(4);
        let reqs: Vec<GenRequest> = (0..n)
            .map(|id| GenRequest {
                id,
                prompt: (0..2 + rng.below(6)).map(|_| rng.below(cfg.vocab) as i32).collect(),
                max_new: Some(1 + rng.below(7)),
                deadline_s: None,
            })
            .collect();
        let budgets: Vec<usize> = reqs.iter().map(|r| r.max_new.unwrap()).collect();
        let config = DecodeConfig {
            slots,
            capacity: 16,
            max_new: 4,
            sampling: Sampling::Greedy,
            seed: case,
            eos: None,
            ..DecodeConfig::default()
        };
        let (results, stats) =
            DecodeScheduler::new(&model, config).run(reqs).unwrap();
        assert_eq!(results.len(), n, "case {case}: every request completes");
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i, "case {case}: results in id order");
            assert_eq!(
                r.admitted,
                Some(i),
                "case {case}: FIFO admission — request {i} was overtaken"
            );
            assert_eq!(
                r.tokens.len(),
                budgets[i],
                "case {case}: greedy without EOS runs to its exact budget"
            );
            assert!(r.ttft_s <= r.latency_s, "case {case}");
        }
        assert!(stats.peak_active <= slots, "case {case}: {} > {slots}", stats.peak_active);
        assert_eq!(
            stats.generated_tokens(),
            budgets.iter().sum::<usize>(),
            "case {case}"
        );
        if n > slots {
            assert!(stats.mid_run_admissions > 0, "case {case}: queue must drain mid-run");
        }
    }
}

/// Property: the row-sharded `par_matmul_*` kernels are bitwise identical
/// to their serial twins for random shapes and any thread count — the
/// exec core's determinism contract at the kernel level.
#[test]
fn prop_par_matmuls_bitwise_equal_serial_for_any_threads() {
    use llm_rom::exec::ExecPool;
    use llm_rom::linalg::{
        matmul_f32, matmul_transb_blocked_f32, par_matmul, par_matmul_f32,
        par_matmul_transb_blocked_f32,
    };
    for case in 0..CASES {
        let mut rng = Rng::new(case * 2657 + 11);
        let m = 1 + rng.below(90);
        let k = 1 + rng.below(60);
        let n = 1 + rng.below(90);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let bt: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        let a64 = Matrix::from_f32(m, k, &a);
        let b64 = Matrix::from_f32(k, n, &b);
        let want = matmul_f32(&a, &b, m, k, n);
        let want_tb = matmul_transb_blocked_f32(&a, &bt, m, k, n);
        let want64 = matmul(&a64, &b64);
        let threads = 1 + rng.below(9);
        let pool = ExecPool::new(threads);
        assert_eq!(
            par_matmul_f32(&a, &b, m, k, n, &pool),
            want,
            "case {case}: {m}x{k}x{n} t{threads}"
        );
        assert_eq!(
            par_matmul_transb_blocked_f32(&a, &bt, m, k, n, &pool),
            want_tb,
            "case {case}: transb {m}x{k}x{n} t{threads}"
        );
        assert_eq!(
            par_matmul(&a64, &b64, &pool).data(),
            want64.data(),
            "case {case}: f64 {m}x{k}x{n} t{threads}"
        );
    }
}

/// Property: the whole compression pipeline is thread-count invariant —
/// the serialized `.rtz` artifact bytes and the accounting of an offline
/// `rom-weight-svd` run are identical at `--threads 1/2/8`.
#[test]
fn prop_artifact_bytes_invariant_to_threads() {
    use llm_rom::exec::ExecConfig;
    let cfg = tiny_cfg();
    let dir = std::env::temp_dir().join(format!("exec_prop_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..4u64 {
        let params = random_params(&cfg, case * 31 + 5);
        let budget = 0.4 + 0.15 * case as f64;
        let artifact_bytes = |threads: usize| {
            let session =
                CompressionSession::offline(cfg.clone()).with_exec(ExecConfig::with_threads(threads));
            let mut cm = session
                .compress_at("rom-weight-svd", &params, budget, &mut EmptyStream)
                .unwrap();
            // timings are wall-clock profiling data and differ run to run
            // even at equal thread counts — blank them so the byte compare
            // covers exactly the deterministic payload (params, factors,
            // accounting, provenance)
            cm.timings.clear();
            let path = dir.join(format!("t{threads}_{case}.rtz"));
            cm.save(&path).unwrap();
            (std::fs::read(&path).unwrap(), cm.accounting.layers.len())
        };
        let (bytes1, layers1) = artifact_bytes(1);
        for threads in [2usize, 8] {
            let (bytes_n, layers_n) = artifact_bytes(threads);
            assert_eq!(layers_n, layers1, "case {case} t{threads}: accounting moved");
            assert_eq!(
                bytes_n, bytes1,
                "case {case} t{threads}: .rtz bytes not identical across thread counts"
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Property: greedy decode token streams (and executed MACs) are invariant
/// to the `--threads` knob for random configs, slot counts, and budgets.
#[test]
fn prop_decode_streams_invariant_to_threads() {
    use llm_rom::decode::{synth_gen_requests, DecodeConfig, DecodeScheduler, Sampling};
    use llm_rom::exec::ExecConfig;
    use llm_rom::serve::{demo_artifact, ExecMode, ServeModel};
    for case in 0..5u64 {
        let mut rng = Rng::new(case * 4241 + 29);
        let cfg = ModelConfig {
            vocab: 40 + rng.below(30),
            d_model: 16,
            n_heads: 2,
            n_layers: 2,
            d_ff: 24,
            ..ModelConfig::mini()
        };
        let cm = demo_artifact(&cfg, 0.4 + rng.f64() * 0.4, case * 3 + 7).unwrap();
        let model = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
        let prompt_len = 3 + rng.below(6);
        let max_new = 3 + rng.below(6);
        let slots = 1 + rng.below(3);
        let reqs = synth_gen_requests(&cfg, 2 + rng.below(4), prompt_len, case * 17 + 1);
        let run = |threads: usize| {
            let config = DecodeConfig {
                slots,
                capacity: prompt_len + max_new,
                max_new,
                sampling: Sampling::Greedy,
                seed: case,
                eos: None,
                exec: ExecConfig::with_threads(threads),
                ..DecodeConfig::default()
            };
            let (results, _) = DecodeScheduler::new(&model, config).run(reqs.clone()).unwrap();
            results.into_iter().map(|r| (r.id, r.tokens, r.macs)).collect::<Vec<_>>()
        };
        let serial = run(1);
        for threads in [2usize, 8] {
            assert_eq!(run(threads), serial, "case {case} t{threads}: streams moved");
        }
    }
}

/// Property: task generators always emit valid instances for random
/// worlds, and calib/eval streams stay disjoint.
#[test]
fn prop_tasks_valid_on_random_worlds() {
    use llm_rom::data::{Split, Task, World, ALL_TASKS};
    for case in 0..12 {
        let mut rng = Rng::new(case * 331 + 17);
        let world = World::generate(
            case * 7 + 1,
            2 + rng.below(40),
            8 + rng.below(24),
            2 + rng.below(12),
        );
        for kind in ALL_TASKS {
            let task = Task::new(&world, kind);
            for inst in task.generate(Split::Eval, 16, case) {
                assert_eq!(inst.choices.len(), kind.n_choices());
                assert!(inst.gold < inst.choices.len());
                let mut c = inst.choices.clone();
                c.sort();
                c.dedup();
                assert_eq!(c.len(), inst.choices.len(), "case {case} {kind:?}: dup choices");
                // prompt+choice must fit the canonical eval window
                for i in 0..inst.choices.len() {
                    assert!(inst.full_text(i).len() + 1 <= 128, "case {case}: too long");
                }
            }
        }
    }
}

/// Property: pack_lm_batches windows are exact substrings with shift-1
/// targets for arbitrary text sizes.
#[test]
fn prop_lm_batches_shift_invariant() {
    use llm_rom::data::{pack_lm_batches, render_corpus, World};
    for case in 0..10 {
        let world = World::default_world(case + 100);
        let text = render_corpus(&world, case, 8_000 + (case as usize) * 997, 1);
        let bs = pack_lm_batches(&text, 3, 24, 4, case);
        for b in &bs {
            for row in 0..3 {
                for t in 0..23 {
                    assert_eq!(b.tokens[row * 24 + t + 1], b.targets[row * 24 + t]);
                }
            }
        }
    }
}

/// Property: the streaming event path is the batch path. For random
/// configs, budgets, slot counts, and thread counts, the concatenated
/// `Token` event payloads of every request equal the batch `run()` token
/// stream, finish reasons and MAC accounting agree, and the event *order*
/// (ids and payloads, timestamps aside) is bitwise invariant to the
/// thread count.
#[test]
fn prop_streaming_events_equal_batch_run() {
    use llm_rom::decode::{
        synth_gen_requests, DecodeConfig, DecodeScheduler, EventKind, Sampling, StreamControl,
    };
    use llm_rom::exec::ExecConfig;
    use llm_rom::serve::{demo_artifact, ExecMode, ServeModel};
    for case in 0..5u64 {
        let mut rng = Rng::new(case * 7121 + 31);
        let cfg = ModelConfig {
            vocab: 40 + rng.below(30),
            d_model: 16,
            n_heads: 2,
            n_layers: 2,
            d_ff: 24,
            ..ModelConfig::mini()
        };
        let cm = demo_artifact(&cfg, 0.4 + rng.f64() * 0.4, case * 5 + 3).unwrap();
        let model = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
        let prompt_len = 3 + rng.below(6);
        let max_new = 2 + rng.below(6);
        let slots = 1 + rng.below(3);
        let n = 2 + rng.below(4);
        let reqs = synth_gen_requests(&cfg, n, prompt_len, case * 19 + 7);
        let config = |threads: usize| DecodeConfig {
            slots,
            capacity: prompt_len + max_new,
            max_new,
            sampling: Sampling::Greedy,
            seed: case,
            eos: None,
            exec: ExecConfig::with_threads(threads),
            ..DecodeConfig::default()
        };

        let sched = DecodeScheduler::new(&model, config(2));
        let (batch, batch_stats) = sched.run(reqs.clone()).unwrap();

        let stream_run = |threads: usize| {
            let sched = DecodeScheduler::new(&model, config(threads));
            let mut events: Vec<(usize, EventKind)> = Vec::new();
            let (results, stats) = sched
                .run_streaming(reqs.clone(), |ev| {
                    events.push((ev.id, strip_times(ev.kind.clone())));
                    StreamControl::Continue
                })
                .unwrap();
            (events, results, stats)
        };

        let (events, streamed, stream_stats) = stream_run(2);
        assert_eq!(streamed.len(), batch.len(), "case {case}");
        for (a, b) in batch.iter().zip(&streamed) {
            assert_eq!(a.id, b.id, "case {case}");
            assert_eq!(a.tokens, b.tokens, "case {case}: streamed result diverged");
            assert_eq!(a.finish, b.finish, "case {case}");
            assert_eq!(a.macs, b.macs, "case {case}");
            let from_events: Vec<i32> = events
                .iter()
                .filter(|(id, _)| *id == a.id)
                .filter_map(|(_, k)| match k {
                    EventKind::Token { token, .. } => Some(*token),
                    _ => None,
                })
                .collect();
            assert_eq!(
                from_events, a.tokens,
                "case {case}: request {} Token events != batch stream",
                a.id
            );
        }
        assert_eq!(stream_stats.core.macs, batch_stats.core.macs, "case {case}");
        assert_eq!(
            stream_stats.generated_tokens(),
            batch_stats.generated_tokens(),
            "case {case}"
        );
        // TTFT/inter-token samples cover the event timeline exactly: one
        // TTFT per request, one inter-token sample per non-first token
        assert_eq!(stream_stats.ttft.n, n, "case {case}");
        assert_eq!(
            stream_stats.inter_token.n,
            stream_stats.generated_tokens() - n,
            "case {case}"
        );

        // event order is bitwise invariant to the thread count
        let (serial_events, _, _) = stream_run(1);
        for threads in [2usize, 8] {
            let (ev_n, _, _) = stream_run(threads);
            assert_eq!(ev_n, serial_events, "case {case} t{threads}: event order moved");
        }
    }
}

/// Event kinds with wall-clock fields zeroed (payload-only comparison).
fn strip_times(kind: llm_rom::decode::EventKind) -> llm_rom::decode::EventKind {
    use llm_rom::decode::EventKind;
    match kind {
        EventKind::Prefilled { prompt_len, .. } => EventKind::Prefilled { prompt_len, ttft_s: 0.0 },
        other => other,
    }
}

/// Property: mid-flight cancellation and deadline eviction keep the
/// partial stream, free the slot for queued requests, and never corrupt
/// the streams of the surviving requests.
#[test]
fn prop_cancellation_preserves_surviving_streams() {
    use llm_rom::decode::{
        synth_gen_requests, DecodeConfig, DecodeScheduler, EventKind, Sampling, StreamControl,
    };
    use llm_rom::serve::{demo_artifact, demo_config, ExecMode, ServeModel};
    let cfg = demo_config();
    let cm = demo_artifact(&cfg, 0.5, 87).unwrap();
    let model = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
    for case in 0..6u64 {
        let mut rng = Rng::new(case * 3931 + 53);
        let n = 3 + rng.below(4);
        // cancel one request after `cut` >= 2 tokens: events are delivered
        // at step boundaries, and a request's first step yields two tokens
        // (prefill + first round), so cut == 1 would still keep two
        let cut = 2 + rng.below(2);
        let victim = rng.below(n);
        let config = DecodeConfig {
            slots: 1 + rng.below(2),
            capacity: 32,
            max_new: 6,
            sampling: Sampling::Greedy,
            seed: case,
            eos: None,
            ..DecodeConfig::default()
        };
        let reqs = synth_gen_requests(&cfg, n, 5, case * 29 + 3);
        let sched = DecodeScheduler::new(&model, config);
        let (base, _) = sched.run(reqs.clone()).unwrap();
        let (got, stats) = sched
            .run_streaming(reqs, |ev| match &ev.kind {
                EventKind::Token { index, .. } if ev.id == victim && index + 1 >= cut => {
                    StreamControl::Cancel
                }
                _ => StreamControl::Continue,
            })
            .unwrap();
        assert_eq!(got.len(), n, "case {case}: every request still completes");
        for (b, g) in base.iter().zip(&got) {
            if g.id == victim {
                assert_eq!(g.finish.name(), "cancelled", "case {case}");
                assert_eq!(g.tokens.len(), cut, "case {case}: partial stream kept");
                assert_eq!(
                    g.tokens[..],
                    b.tokens[..cut],
                    "case {case}: partial stream must be a prefix of the full one"
                );
            } else {
                assert_eq!(g.tokens, b.tokens, "case {case}: survivor {} corrupted", g.id);
                assert_eq!(g.finish, b.finish, "case {case}");
            }
        }
        assert_eq!(stats.core.requests, n, "case {case}");
    }
}

/// Property: a batch flood never starves the interactive tier. For random
/// flood sizes, slot counts, and generation budgets, interactive requests
/// landing mid-flood are the very next admissions (in their own arrival
/// order), their queue wait is bounded in scheduling rounds — independent
/// of the flood size — and every request in both tiers still completes.
#[test]
fn prop_batch_flood_never_starves_interactive() {
    use llm_rom::decode::Sampling;
    use llm_rom::engine::{
        synth_token_streams, EngineConfig, EngineCore, EventKind, InferenceRequest, Tier,
    };
    use llm_rom::serve::{demo_artifact, demo_config, ExecMode, ServeModel};
    let cfg = demo_config();
    let cm = demo_artifact(&cfg, 0.5, 91).unwrap();
    let model = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
    for case in 0..8u64 {
        let mut rng = Rng::new(case * 5087 + 59);
        let n_batch = 4 + rng.below(8);
        let n_int = 1 + rng.below(3);
        let slots = 1 + rng.below(2);
        let prompt_len = 3 + rng.below(4);
        let max_new = 2 + rng.below(4);
        let total = n_batch + n_int;
        let ecfg = EngineConfig {
            slots,
            queue_cap: total,
            capacity: prompt_len + max_new,
            max_new,
            sampling: Sampling::Greedy,
            seed: case,
            eos: None,
            ..EngineConfig::default()
        };
        let prompts = synth_token_streams(&cfg, total, prompt_len, case * 23 + 9);
        let mut session = EngineCore::new(&model, ecfg).session();
        // the flood queues first and takes every slot
        for id in 0..n_batch {
            let req = InferenceRequest::generate(id, prompts[id].clone(), None);
            assert!(session.try_submit(req).unwrap().is_none(), "case {case}: flood bounced");
        }
        let warm = 1 + rng.below(max_new);
        let mut round = 0usize;
        for _ in 0..warm {
            session.step().unwrap();
            round += 1;
        }
        session.take_events();
        // ...then the interactive trickle lands mid-flood
        let submit_round = round;
        for k in 0..n_int {
            let id = n_batch + k;
            let req = InferenceRequest::generate(id, prompts[id].clone(), None)
                .with_tier(Tier::Interactive);
            assert!(session.try_submit(req).unwrap().is_none(), "case {case}: trickle bounced");
        }
        let mut admitted_after: Vec<(usize, usize)> = Vec::new(); // (id, round)
        while session.has_work() {
            session.step().unwrap();
            round += 1;
            for ev in session.take_events() {
                if matches!(ev.kind, EventKind::Admitted { .. }) {
                    admitted_after.push((ev.id, round));
                }
            }
        }
        // interactive requests are the very next admissions, in arrival
        // order — the queued remainder of the flood never overtakes them
        let next: Vec<usize> = admitted_after.iter().take(n_int).map(|(id, _)| *id).collect();
        let want: Vec<usize> = (n_batch..total).collect();
        assert_eq!(next, want, "case {case}: flood overtook the interactive tier");
        // bounded wait, independent of the flood size: at worst every slot
        // must drain one full generation, plus the interactive requests
        // admitted ahead of this one
        let bound = max_new * (n_int + 1);
        for &(id, r) in admitted_after.iter().take(n_int) {
            let wait = r - submit_round;
            assert!(
                wait <= bound,
                "case {case}: interactive {id} waited {wait} rounds (bound {bound})"
            );
        }
        // and nothing starves in either tier
        let (_, stats) = session.finish();
        assert_eq!(stats.requests, total, "case {case}: a request starved");
        assert_eq!(stats.preemptions, 0, "case {case}: unlimited meter must never preempt");
    }
}

/// Property: the causal-plane flight recorder is a faithful transcript.
/// For random tiered multi-tenant workloads, the recorded event stream is
/// bitwise identical at `--threads 1/2/8`, and replaying it through
/// `obs::reconstruct` recovers the session's `CoreStats` accounting
/// exactly — requests, preemptions, decode rounds, admitted and executed
/// MACs, and the per-tenant fairness ledger — while the timing-plane
/// registry's counters agree with the same totals.
#[test]
fn prop_flight_recorder_reconstructs_core_stats_across_threads() {
    use llm_rom::decode::Sampling;
    use llm_rom::engine::{
        synth_token_streams, EngineConfig, EngineCore, InferenceRequest, Tier,
    };
    use llm_rom::exec::ExecConfig;
    use llm_rom::obs::{self, MetricsRegistry};
    use llm_rom::serve::{demo_artifact, demo_config, ExecMode, ServeModel};
    use std::sync::Arc;

    let cfg = demo_config();
    let cm = demo_artifact(&cfg, 0.5, 97).unwrap();
    let model = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
    for case in 0..6u64 {
        let mut rng = Rng::new(case * 9631 + 67);
        let n = 3 + rng.below(8);
        let prompt_len = 3 + rng.below(5);
        let max_new = 2 + rng.below(4);
        let slots = 1 + rng.below(2);
        let prompts = synth_token_streams(&cfg, n, prompt_len, case * 37 + 5);
        // per-request shape: (score?, interactive?, tenant, token budget).
        // Deadlines stay None — deadline eviction is wall-clock driven and
        // would make the transcript timing-dependent.
        let shapes: Vec<(bool, bool, Option<&str>, Option<usize>)> = (0..n)
            .map(|_| {
                (
                    rng.chance(0.25),
                    rng.chance(0.35),
                    *rng.choose(&[None, Some("alpha"), Some("beta")]),
                    if rng.chance(0.5) { Some(1 + rng.below(max_new)) } else { None },
                )
            })
            .collect();
        let run = |threads: usize| {
            let ecfg = EngineConfig {
                slots,
                queue_cap: n,
                capacity: prompt_len + max_new,
                max_new,
                sampling: Sampling::Greedy,
                seed: case,
                eos: None,
                exec: ExecConfig::with_threads(threads),
                ..EngineConfig::default()
            };
            let registry = Arc::new(MetricsRegistry::new());
            let mut session = EngineCore::new(&model, ecfg).session();
            session.enable_tracing(obs::DEFAULT_TRACE_CAP);
            session.attach_metrics(Arc::clone(&registry));
            for (id, &(score, interactive, tenant, budget)) in shapes.iter().enumerate() {
                let mut req = if score {
                    InferenceRequest::score(id, prompts[id].clone())
                } else {
                    InferenceRequest::generate(id, prompts[id].clone(), budget)
                };
                if interactive {
                    req = req.with_tier(Tier::Interactive);
                }
                if let Some(t) = tenant {
                    req = req.with_tenant(t);
                }
                assert!(
                    session.try_submit(req).unwrap().is_none(),
                    "case {case} t{threads}: request {id} bounced"
                );
            }
            while session.has_work() {
                session.step().unwrap();
            }
            let trace = session.take_trace();
            let (_, stats) = session.finish();
            (trace, stats, registry)
        };

        let (trace, stats, registry) = run(1);
        // the transcript replays into the engine's own accounting
        let replay = obs::reconstruct(&trace);
        assert_eq!(replay.enqueued, n, "case {case}");
        assert_eq!(replay.admitted, n, "case {case}: an admission went unrecorded");
        assert_eq!(replay.finished, stats.requests, "case {case}");
        assert_eq!(replay.preemptions, stats.preemptions, "case {case}");
        assert_eq!(replay.decode_rounds, stats.decode_rounds, "case {case}");
        assert_eq!(replay.admitted_macs, stats.admitted_macs, "case {case}");
        assert_eq!(replay.executed_macs, stats.macs, "case {case}");
        let ledger: std::collections::BTreeMap<String, (usize, u128)> = stats
            .tenants
            .iter()
            .map(|(k, u)| (k.clone(), (u.requests, u.declared_macs)))
            .collect();
        assert_eq!(replay.tenants, ledger, "case {case}: tenant ledger diverged");
        // the timing plane counts the same totals
        assert_eq!(registry.requests.get(), stats.requests as u64, "case {case}");
        assert_eq!(registry.preemptions.get(), stats.preemptions as u64, "case {case}");
        assert_eq!(registry.decode_rounds.get(), stats.decode_rounds as u64, "case {case}");
        assert_eq!(registry.executed_macs.get(), obs::sat_u64(stats.macs), "case {case}");
        assert_eq!(
            registry.admitted_macs.get(),
            obs::sat_u64(stats.admitted_macs),
            "case {case}"
        );
        // and the whole transcript is invariant to the thread count
        for threads in [2usize, 8] {
            let (trace_n, stats_n, _) = run(threads);
            assert_eq!(
                trace_n, trace,
                "case {case} t{threads}: causal-plane transcript moved"
            );
            assert_eq!(stats_n.macs, stats.macs, "case {case} t{threads}");
            assert_eq!(stats_n.requests, stats.requests, "case {case} t{threads}");
        }
    }
}

/// Property: the SIMD microkernels are bitwise equal to their scalar
/// oracles on shapes straddling the lane widths. `dot_f32` must equal the
/// scalar lane-emulation oracle `dot_f32_ref` exactly; the packed-panel
/// kernel must equal the blocked kernel exactly (zero-padded panels are a
/// bitwise no-op); and the row-sharded packed/quantized kernels must be
/// bitwise invariant to the thread count.
#[test]
fn prop_simd_kernels_bitwise_equal_scalar() {
    use llm_rom::exec::ExecPool;
    use llm_rom::linalg::{
        dot_f32, dot_f32_ref, matmul_transb_blocked_into, matmul_transb_packed_into,
        matmul_transb_quant_into, par_matmul_transb_packed_into, par_matmul_transb_quant_into,
        PackedWeight, QuantizedWeight,
    };
    // straddles both the 8-lane dot width and the 4-row panel height
    const DIMS: &[usize] = &[1, 2, 3, 4, 5, 6, 7, 8, 9, 63, 64, 65, 129];
    for case in 0..CASES {
        let mut rng = Rng::new(case * 12713 + 71);
        let m = *rng.choose(DIMS);
        let k = *rng.choose(DIMS);
        let n = *rng.choose(DIMS);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let bt: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();

        // vectorized dot == its scalar lane-emulation oracle, bitwise
        for j in 0..n.min(4) {
            let row = &bt[j * k..(j + 1) * k];
            let x = &a[..k];
            assert_eq!(
                dot_f32(x, row).to_bits(),
                dot_f32_ref(x, row).to_bits(),
                "case {case}: dot k={k} row {j}"
            );
        }

        // packed panels == the blocked kernel, bitwise
        let mut blocked = vec![0.0f32; m * n];
        matmul_transb_blocked_into(&a, &bt, m, k, n, &mut blocked);
        let packed = PackedWeight::pack(&bt, n, k);
        let mut from_packed = vec![0.0f32; m * n];
        matmul_transb_packed_into(&a, &packed, m, &mut from_packed);
        assert_eq!(blocked, from_packed, "case {case}: packed != blocked {m}x{k}x{n}");

        // row-sharding never moves a bit, packed and quantized alike
        let quant = QuantizedWeight::quantize(&bt, n, k);
        let mut qserial = vec![0.0f32; m * n];
        matmul_transb_quant_into(&a, &quant, m, &mut qserial);
        let threads = 2 + rng.below(7);
        let pool = ExecPool::new(threads);
        let mut par = vec![0.0f32; m * n];
        par_matmul_transb_packed_into(&a, &packed, m, &pool, &mut par);
        assert_eq!(par, from_packed, "case {case}: packed moved under t{threads}");
        let mut qpar = vec![0.0f32; m * n];
        par_matmul_transb_quant_into(&a, &quant, m, &pool, &mut qpar);
        assert_eq!(qpar, qserial, "case {case}: quant moved under t{threads}");
    }
}

/// Property: across random budgets and seeds, the int8 quantized factored
/// path stays within its stated tolerance of the f32 factored path on
/// logits and executes exactly the same MACs (quantization changes bytes,
/// not arithmetic shape).
#[test]
fn prop_factored_quant_tracks_f32_factored() {
    use llm_rom::serve::{demo_artifact, demo_config, synth_requests, ExecMode, ServeModel};
    let cfg = demo_config();
    for case in 0..8u64 {
        let mut rng = Rng::new(case * 10627 + 73);
        let budget = 0.4 + rng.f64() * 0.6;
        let cm = demo_artifact(&cfg, budget, case * 3 + 2).unwrap();
        let fact = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
        let quant = ServeModel::from_artifact(&cm, ExecMode::FactoredQuant).unwrap();
        for req in synth_requests(&cfg, 2, 6 + rng.below(12), case * 17 + 5) {
            let (lf, mf) = fact.forward_logits(&req.tokens).unwrap();
            let (lq, mq) = quant.forward_logits(&req.tokens).unwrap();
            assert_eq!(mq, mf, "case {case} b={budget:.2}: quant MACs != factored MACs");
            let mag = lf.iter().fold(0.0f64, |x, v| x.max(v.abs() as f64));
            let bound = 0.1 * mag.max(1.0);
            let diff = lf
                .iter()
                .zip(&lq)
                .map(|(a, b)| (a - b).abs() as f64)
                .fold(0.0f64, f64::max);
            assert!(
                diff <= bound,
                "case {case} b={budget:.2}: max |Δlogits| = {diff:.3e} (bound {bound:.3e})"
            );
        }
        // budget 1.0 carries no factors: every mode is the dense graph,
        // so the quantized path is bitwise dense
        let id = demo_artifact(&cfg, 1.0, case).unwrap();
        let dense = ServeModel::from_artifact(&id, ExecMode::Dense).unwrap();
        let dq = ServeModel::from_artifact(&id, ExecMode::FactoredQuant).unwrap();
        let toks = &synth_requests(&cfg, 1, 8, case)[0].tokens;
        assert_eq!(
            dense.forward_logits(toks).unwrap(),
            dq.forward_logits(toks).unwrap(),
            "case {case}: factor-free artifact must serve bitwise dense in quant mode"
        );
    }
}

/// Property: speculative greedy decode is bitwise identical to
/// verifier-only greedy decode for random configs, draft/verifier budget
/// pairs of the same checkpoint, spec-k values, and thread counts; the
/// MACs it executes equal the analytic speculative accounting
/// (`decode_report` prefill + `spec_report` spec MACs) exactly, rollback
/// waste included; and the acceptance counters are invariant to
/// `--threads`.
#[test]
fn prop_speculative_equals_verifier_greedy() {
    use llm_rom::decode::{
        synth_gen_requests, DecodeConfig, DecodeScheduler, Sampling, SpecDecoder,
    };
    use llm_rom::exec::ExecConfig;
    use llm_rom::model::macs::{decode_report, spec_report};
    use llm_rom::serve::{demo_artifact, ExecMode, ServeModel};
    for case in 0..5u64 {
        let mut rng = Rng::new(case * 11717 + 79);
        let cfg = ModelConfig {
            vocab: 40 + rng.below(30),
            d_model: 16,
            n_heads: 2,
            n_layers: 2,
            d_ff: 24,
            ..ModelConfig::mini()
        };
        // same seed => same synthetic checkpoint; the draft is just a
        // harder compression of it, so `check_spec_draft` holds
        let ckpt_seed = case * 3 + 7;
        let vcm = demo_artifact(&cfg, 0.6 + rng.f64() * 0.35, ckpt_seed).unwrap();
        let dcm = demo_artifact(&cfg, 0.25 + rng.f64() * 0.2, ckpt_seed).unwrap();
        let verifier = ServeModel::from_artifact(&vcm, ExecMode::Factored).unwrap();
        let draft = ServeModel::from_artifact(&dcm, ExecMode::Factored).unwrap();
        let prompt_len = 3 + rng.below(6);
        let max_new = 3 + rng.below(7);
        let slots = 1 + rng.below(3);
        let spec_k = 1 + rng.below(5);
        let reqs = synth_gen_requests(&cfg, 2 + rng.below(4), prompt_len, case * 13 + 11);
        let config = |threads: usize, spec_k: usize| DecodeConfig {
            slots,
            capacity: prompt_len + max_new,
            max_new,
            sampling: Sampling::Greedy,
            seed: case,
            eos: None,
            spec_k,
            exec: ExecConfig::with_threads(threads),
            ..DecodeConfig::default()
        };
        // verifier-only greedy reference
        let (base, _) =
            DecodeScheduler::new(&verifier, config(1, 0)).run(reqs.clone()).unwrap();

        // per-request reference decoder: bitwise streams + exact MACs
        let spec = SpecDecoder::from_artifacts(&vcm, &dcm, ExecMode::Factored, spec_k).unwrap();
        let mut ref_macs: Vec<u128> = Vec::new();
        for (req, b) in reqs.iter().zip(&base) {
            let stream =
                spec.generate(&req.prompt, max_new, None, ExecConfig::serial()).unwrap();
            assert_eq!(
                stream.tokens, b.tokens,
                "case {case} k={spec_k}: spec stream diverged (request {})",
                req.id
            );
            let want = decode_report(&cfg, &vcm.accounting, req.prompt.len(), 1).prefill_macs
                + spec_report(
                    &cfg,
                    &dcm.accounting,
                    &vcm.accounting,
                    req.prompt.len(),
                    &stream.rounds,
                )
                .spec_macs();
            assert_eq!(
                stream.macs, want,
                "case {case} k={spec_k}: executed != analytic (request {})",
                req.id
            );
            ref_macs.push(stream.macs);
        }

        // engine path: streams bitwise equal to the verifier-only run, lane
        // MACs equal the reference decoder's, acceptance thread-invariant
        let run = |threads: usize| {
            let (results, stats) =
                DecodeScheduler::with_draft(&verifier, &draft, config(threads, spec_k))
                    .unwrap()
                    .run(reqs.clone())
                    .unwrap();
            let rows = results
                .into_iter()
                .map(|r| (r.id, r.tokens, r.macs, r.finish.name()))
                .collect::<Vec<_>>();
            (rows, stats.spec_drafted, stats.spec_accepted)
        };
        let (sp1, drafted1, accepted1) = run(1);
        for (i, ((id, tokens, macs, _), b)) in sp1.iter().zip(&base).enumerate() {
            assert_eq!(*id, b.id, "case {case}");
            assert_eq!(
                tokens, &b.tokens,
                "case {case} k={spec_k}: engine spec stream diverged (request {id})"
            );
            assert_eq!(
                *macs, ref_macs[i],
                "case {case} k={spec_k}: engine lane MACs != reference (request {id})"
            );
        }
        assert!(drafted1 > 0, "case {case} k={spec_k}: nothing was drafted");
        assert!(accepted1 <= drafted1, "case {case}");
        for threads in [2usize, 8] {
            let (spn, dn, an) = run(threads);
            assert_eq!(spn, sp1, "case {case} t{threads}: speculative outcome moved");
            assert_eq!(
                (dn, an),
                (drafted1, accepted1),
                "case {case} t{threads}: acceptance counters moved"
            );
        }
    }
}

/// Property: the FIFO-reduction bar. With a single tier, no deadlines, and
/// an unlimited meter, the priced scheduler is bitwise FIFO — admission
/// order equals submission order — and the whole outcome (admission seqs,
/// token streams, MACs, finish reasons) is invariant to `--threads`,
/// across random configs, slot counts, and workload shapes.
#[test]
fn prop_engine_single_tier_reduces_to_fifo_across_threads() {
    use llm_rom::decode::Sampling;
    use llm_rom::engine::{synth_generate_requests, EngineConfig, EngineCore};
    use llm_rom::exec::ExecConfig;
    use llm_rom::serve::{demo_artifact, ExecMode, ServeModel};
    for case in 0..5u64 {
        let mut rng = Rng::new(case * 8209 + 61);
        let cfg = ModelConfig {
            vocab: 40 + rng.below(30),
            d_model: 16,
            n_heads: 2,
            n_layers: 2,
            d_ff: 24,
            ..ModelConfig::mini()
        };
        let cm = demo_artifact(&cfg, 0.4 + rng.f64() * 0.4, case * 11 + 5).unwrap();
        let model = ServeModel::from_artifact(&cm, ExecMode::Factored).unwrap();
        let prompt_len = 3 + rng.below(5);
        let max_new = 2 + rng.below(5);
        let slots = 1 + rng.below(3);
        let n = 2 + rng.below(6);
        let reqs = synth_generate_requests(&cfg, n, prompt_len, case * 41 + 3);
        let run = |threads: usize| {
            let ecfg = EngineConfig {
                slots,
                queue_cap: n,
                capacity: prompt_len + max_new,
                max_new,
                sampling: Sampling::Greedy,
                seed: case,
                eos: None,
                exec: ExecConfig::with_threads(threads),
                ..EngineConfig::default()
            };
            let (finished, stats) = EngineCore::new(&model, ecfg).run(reqs.clone()).unwrap();
            assert_eq!(stats.preemptions, 0, "case {case} t{threads}: FIFO config preempted");
            finished
                .into_iter()
                .map(|f| (f.id, f.admitted, f.tokens, f.macs, f.reason.name()))
                .collect::<Vec<_>>()
        };
        let base = run(1);
        for (i, f) in base.iter().enumerate() {
            assert_eq!(
                f.1,
                Some(i),
                "case {case}: request {i} overtaken — single tier must reduce to FIFO"
            );
        }
        for threads in [2usize, 8] {
            assert_eq!(run(threads), base, "case {case} t{threads}: scheduling moved");
        }
    }
}
