//! Compile-time stub of the `xla` PJRT bindings.
//!
//! The real crate links the PJRT C API and the XLA CPU plugin, which are
//! not present in every build environment. This stub carries the exact
//! type/method surface `llm_rom::runtime` consumes so the workspace always
//! compiles; every entry point fails at `PjRtClient::cpu()` with a clear
//! message, which the callers (CLI, examples, integration tests) treat as
//! "AOT runtime unavailable — skip". To execute the AOT artifacts, point
//! the `xla` path dependency in `rust/Cargo.toml` at the real bindings —
//! no `llm_rom` source changes are needed.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

const UNAVAILABLE: &str =
    "PJRT backend unavailable: built against the xla stub (see rust/vendor/xla)";

/// Error type of the stubbed bindings.
pub struct XlaError(String);

impl XlaError {
    fn unavailable() -> XlaError {
        XlaError(UNAVAILABLE.to_string())
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

/// Element types of the literals the runtime marshals. The full bindings
/// expose many more; carrying a superset here keeps wildcard match arms
/// in consumers reachable (no `unreachable_patterns` warnings).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U8,
    F32,
    F64,
    Bf16,
}

/// Host-side literal (opaque in the stub; never instantiated).
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(XlaError::unavailable())
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(XlaError::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(XlaError::unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(XlaError::unavailable())
    }
}

/// Shape of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Parsed HLO module (opaque).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(XlaError::unavailable())
    }
}

/// XLA computation handle (opaque).
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-side buffer returned by execution (opaque).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::unavailable())
    }
}

/// Compiled executable handle (opaque).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::unavailable())
    }
}

/// PJRT client handle. In the stub, [`PjRtClient::cpu`] always fails — the
/// single choke point that makes the whole runtime report "unavailable".
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_constructors_fail_cleanly() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0; 8])
            .is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo").is_err());
    }
}
