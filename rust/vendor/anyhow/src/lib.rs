//! Offline stand-in for the `anyhow` crate, carrying exactly the API
//! surface this workspace uses: [`Error`], [`Result`], the [`Context`]
//! extension trait for `Result`/`Option`, and the `anyhow!` / `bail!` /
//! `ensure!` macros. Error values are a flattened message chain — context
//! layers are joined with `": "` like anyhow's `{:#}` formatting — which
//! keeps diagnostics readable without carrying backtraces or dyn chains.

use std::fmt;

/// A flattened error message (context chain joined with `": "`).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (`anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer (used by the [`Context`] impls).
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — plain `Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures of a `Result` or emptiness of an `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn bail_and_context_chain() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 42");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing x");
    }

    #[test]
    fn from_std_error_via_question_mark() {
        fn parse() -> Result<i32> {
            let n: i32 = "nope".parse()?;
            Ok(n)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(-1).is_err());
    }
}
