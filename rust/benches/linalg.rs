//! Microbenchmarks for the linalg substrate — the CPU primitives behind
//! the paper's "ROM on CPU in seconds per layer" claim (§4).
//!
//! Cases are sized to the MiniLLaMA ROM pass (d = 128 attention, 344 FFN).

use std::time::Duration;

use llm_rom::exec::ExecPool;
use llm_rom::linalg::{
    eigh, eigh_jacobi, matmul, matmul_transb_blocked_f32, matmul_transb_f32,
    par_matmul_transb_blocked_f32, Matrix,
};
use llm_rom::util::bench::{bench, default_window};
use llm_rom::util::Rng;

fn random_sym(n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let mut m = Matrix::from_fn(n, n, |_, _| rng.normal());
    m.symmetrize();
    m
}

fn random_mat(r: usize, c: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(r, c, |_, _| rng.normal())
}

fn main() {
    let w = default_window();
    println!("# linalg microbench (window {w:?})");

    // eigensolver at the two ROM covariance sizes
    for &n in &[128usize, 344] {
        let a = random_sym(n, n as u64);
        bench(&format!("eigh_ql_{n}x{n}"), w, || eigh(&a).unwrap());
    }
    // jacobi oracle at the small size (cross-check cost)
    let a128 = random_sym(128, 9);
    bench("eigh_jacobi_128x128", Duration::from_secs_f64(w.as_secs_f64().min(2.0)), || {
        eigh_jacobi(&a128).unwrap()
    });

    // re-parameterization matmuls: V_r W and W1 W2 at 80% budget ranks
    let vr = random_mat(29, 128, 1);
    let wq = random_mat(128, 128, 2);
    bench("reparam_VrW_attn(29x128 @ 128x128)", w, || matmul(&vr, &wq));
    let w1 = random_mat(344, 42, 3);
    let w2 = random_mat(42, 128, 4);
    bench("reparam_W1W2_ffn(344x42 @ 42x128)", w, || matmul(&w1, &w2));

    // rust covariance fallback at one calibration chunk (4096 x 128)
    let mut rng = Rng::new(5);
    let y: Vec<f32> = (0..4096 * 128).map(|_| rng.normal() as f32).collect();
    bench("gram_rust_f32_4096x128", w, || {
        let mut acc = llm_rom::rom::CovarianceAccumulator::new(128);
        acc.update_rows(&y, 4096, None).unwrap();
        acc.finalize(false)
    });

    // factored vs dense forward in rust f32 (MACs-proportionality check)
    let x: Vec<f32> = (0..4096 * 128).map(|_| rng.normal() as f32).collect();
    let wd: Vec<f32> = (0..128 * 128).map(|_| rng.normal() as f32).collect();
    bench("dense_fwd_f32 (4096x128 @ 128x128)", w, || {
        matmul_transb_f32(&x, &wd, 4096, 128, 128)
    });
    let w2f: Vec<f32> = (0..29 * 128).map(|_| rng.normal() as f32).collect();
    let w1f: Vec<f32> = (0..128 * 29).map(|_| rng.normal() as f32).collect();
    bench("lowrank_fwd_f32 r=29 (two matmuls)", w, || {
        let t = matmul_transb_f32(&x, &w2f, 4096, 128, 29);
        matmul_transb_f32(&t, &w1f, 4096, 29, 128)
    });

    // row-sharded serving kernel: serial vs the worker pool (the exec
    // core's speedup on the batched-forward hot path)
    bench("serve_kernel_serial (4096x128 @ 128x128ᵀ)", w, || {
        matmul_transb_blocked_f32(&x, &wd, 4096, 128, 128)
    });
    for threads in [2usize, 4] {
        let pool = ExecPool::new(threads);
        bench(&format!("serve_kernel_par_t{threads} (4096x128 @ 128x128ᵀ)"), w, || {
            par_matmul_transb_blocked_f32(&x, &wd, 4096, 128, 128, &pool)
        });
    }
}
