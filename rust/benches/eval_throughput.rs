//! Evaluation-path throughput: score_fwd batches/sec and instances/sec —
//! the serving-side cost of the zero-shot harness that regenerates
//! Tables 1-4 (and the place where a compressed model's MAC savings would
//! surface on accelerators).
//!
//! Needs artifacts (`make artifacts`); skips gracefully otherwise.

use llm_rom::coordinator::{Experiment, ExperimentConfig};
use llm_rom::data::{encode_mc_batches, Split, Task, TaskKind};
use llm_rom::eval::Evaluator;
use llm_rom::runtime::Runtime;
use llm_rom::tensor::Tensor;
use llm_rom::util::bench::{bench, default_window};

fn main() {
    let Ok(rt) = Runtime::new(llm_rom::DEFAULT_ARTIFACTS) else {
        eprintln!("skipping eval bench: artifacts missing (run `make artifacts`)");
        return;
    };
    let w = default_window();
    println!("# eval_throughput bench (platform {})", rt.platform());
    let exp = Experiment::new(&rt, ExperimentConfig::default());
    let params = exp.init_params(llm_rom::DEFAULT_ARTIFACTS).expect("init params");
    let (eb, es) = (exp.cfg.eval_batch, exp.cfg.eval_seq);

    // one raw score_fwd batch
    let task = Task::new(&exp.world, TaskKind::BoolLike);
    let insts = task.generate(Split::Eval, eb, 0);
    let mb = &encode_mc_batches(&insts, eb, es).unwrap()[0];
    let tokens = Tensor::from_i32(&[eb, es], mb.tokens.clone());
    let targets = Tensor::from_i32(&[eb, es], mb.targets.clone());
    let mask = Tensor::from_f32(&[eb, es], mb.mask.clone());
    let mut args: Vec<&Tensor> = params.flat();
    args.push(&tokens);
    args.push(&targets);
    args.push(&mask);
    let r = bench("score_fwd one batch (32x128)", w, || {
        rt.execute("score_fwd", &args).unwrap()
    });
    println!("    -> {:.1} sequences/s", eb as f64 / r.mean_s);

    // end-to-end task evaluation (32 instances)
    let evaluator = Evaluator::new(&rt);
    let insts = task.generate(Split::Eval, 32, 1);
    let r = bench("eval_task synth-boolq (32 instances)", w, || {
        evaluator.eval_task(&params, &insts).unwrap()
    });
    println!("    -> {:.1} instances/s", 32.0 / r.mean_s);

    // forward_logits (generation-style path)
    let spec = rt.manifest().entry("forward_logits").unwrap().clone();
    let toks = Tensor::from_i32(
        &spec.args.last().unwrap().shape,
        vec![1i32; eb * es],
    );
    let mut args: Vec<&Tensor> = params.flat();
    args.push(&toks);
    let r = bench("forward_logits (32x128)", w, || {
        rt.execute("forward_logits", &args).unwrap()
    });
    println!("    -> {:.0} tokens/s", (eb * es) as f64 / r.mean_s);
}
