//! End-to-end ROM-of-one-module benchmark — the paper's §4 "13 s per
//! layer" analog, measured on the real pipeline (capture → covariance →
//! eigendecomposition → re-parameterization) at several calibration sizes,
//! with both covariance backends (Pallas Gram kernel vs pure Rust).
//!
//! Needs artifacts (`make artifacts`); skips gracefully otherwise.

use llm_rom::coordinator::{Experiment, ExperimentConfig};
use llm_rom::rom::{ModuleSchedule, RomConfig, RomPipeline};
use llm_rom::runtime::Runtime;
use llm_rom::util::bench::bench;

fn main() {
    let Ok(rt) = Runtime::new(llm_rom::DEFAULT_ARTIFACTS) else {
        eprintln!("skipping rom_layer bench: artifacts missing (run `make artifacts`)");
        return;
    };
    println!("# rom_layer bench (platform {})", rt.platform());
    let exp = Experiment::new(&rt, ExperimentConfig::default());
    let params = exp.init_params(llm_rom::DEFAULT_ARTIFACTS).expect("init params");
    let pipeline = RomPipeline::new(&rt);

    // compress only the last module, at two calibration sizes (512 rows
    // is measured once in `repro cost`; here we keep the bench window
    // tractable on a 1-core box)
    let last = exp.cfg.n_layers - 1;
    for &rows in &[32usize, 128] {
        let calib = exp.calibration(rows, exp.xcfg.calib_seq, exp.xcfg.calib_source);
        for pallas in [true, false] {
            let rcfg = RomConfig {
                schedule: ModuleSchedule { start_block: last, module_budget: 0.46 },
                pallas_covariance: pallas,
                ..RomConfig::default()
            };
            let label = format!(
                "rom_one_module rows={rows} cov={}",
                if pallas { "pallas" } else { "rust" }
            );
            let window = std::time::Duration::from_secs_f64(2.0);
            let r = bench(&label, window, || {
                pipeline.compress(&params, &calib, &rcfg).expect("compress")
            });
            // derived: seconds per "layer" (7 matrices per module)
            println!("    -> {:.3} s/layer (paper: 13 s/layer on LLaMA-7B)", r.mean_s / 7.0);
        }
    }
}
