//! End-to-end ROM-of-one-module benchmark — the paper's §4 "13 s per
//! layer" analog, measured on the real pipeline (capture → covariance →
//! eigendecomposition → re-parameterization) at several calibration sizes,
//! with both covariance backends (Pallas Gram kernel vs pure Rust) — plus
//! a per-method baseline driving every registered compressor through the
//! unified `Compressor` trait at a fixed budget.
//!
//! Also benches the serving-side dense-vs-factored layer apply (the
//! `d1·d2` vs `r(d1+d2)` MAC argument as wall clock) — that part is pure
//! Rust and needs no artifacts.
//!
//! The pipeline benches need artifacts (`make artifacts`); they skip
//! gracefully otherwise.

use llm_rom::compress::{all, CompressionSession, VecStream};
use llm_rom::coordinator::{Experiment, ExperimentConfig};
use llm_rom::linalg::{matmul, Matrix};
use llm_rom::rom::{ModuleSchedule, RomConfig, RomPipeline};
use llm_rom::runtime::Runtime;
use llm_rom::serve::ServeLayer;
use llm_rom::util::bench::bench;
use llm_rom::util::Rng;

/// Dense vs factored apply of one decomposed layer, at LLaMA-ish shapes
/// scaled down and the paper's 0.46/0.33 module budgets.
fn bench_serve_layer(window: std::time::Duration) {
    println!("# serve layer apply: dense W_eff vs factored (x·W2ᵀ)·W1ᵀ");
    let rows = 64; // tokens per batch
    for &(d_out, d_in, budget) in &[(512usize, 512usize, 0.46f64), (688, 256, 0.33)] {
        let rank = llm_rom::rom::rank_for_budget(d_out, d_in, budget);
        let mut rng = Rng::new(d_out as u64);
        let w1 = Matrix::from_fn(d_out, rank, |_, _| rng.normal() * 0.1);
        let w2 = Matrix::from_fn(rank, d_in, |_, _| rng.normal() * 0.1);
        let weff = matmul(&w1, &w2);
        let dense = ServeLayer::dense(weff.to_f32(), d_out, d_in);
        let fact = ServeLayer::factored_from_matrices(&w1, &w2);
        let x: Vec<f32> = (0..rows * d_in).map(|_| rng.normal() as f32).collect();
        let d = bench(
            &format!("apply dense    {d_out}x{d_in} ({} MACs/row)", dense.macs_per_row()),
            window,
            || dense.apply(&x, rows),
        );
        let f = bench(
            &format!("apply factored {d_out}x{d_in} r={rank} ({} MACs/row)", fact.macs_per_row()),
            window,
            || fact.apply(&x, rows),
        );
        println!(
            "    -> {:.2}x MAC reduction, {:.2}x wall-clock speedup",
            dense.macs_per_row() as f64 / fact.macs_per_row() as f64,
            d.mean_s / f.mean_s
        );
    }
}

fn main() {
    let window = std::time::Duration::from_secs_f64(2.0);
    bench_serve_layer(window);

    let Ok(rt) = Runtime::new(llm_rom::DEFAULT_ARTIFACTS) else {
        eprintln!("skipping rom_layer pipeline bench: artifacts or PJRT runtime missing (run `make artifacts`)");
        return;
    };
    println!("# rom_layer bench (platform {})", rt.platform());
    let exp = Experiment::new(&rt, ExperimentConfig::default());
    let params = exp.init_params(llm_rom::DEFAULT_ARTIFACTS).expect("init params");
    let pipeline = RomPipeline::new(&rt);

    // compress only the last module, at two calibration sizes (512 rows
    // is measured once in `repro cost`; here we keep the bench window
    // tractable on a 1-core box)
    let last = exp.cfg.n_layers - 1;
    let sched = ModuleSchedule { start_block: last, module_budget: 0.46 };
    for &rows in &[32usize, 128] {
        let calib = exp.calibration(rows, exp.xcfg.calib_seq, exp.xcfg.calib_source);
        for pallas in [true, false] {
            let rcfg = RomConfig { schedule: sched, pallas_covariance: pallas, ..RomConfig::default() };
            let label = format!(
                "rom_one_module rows={rows} cov={}",
                if pallas { "pallas" } else { "rust" }
            );
            let r = bench(&label, window, || {
                pipeline.compress(&params, &calib, &rcfg).expect("compress")
            });
            // derived: seconds per "layer" (7 matrices per module)
            println!("    -> {:.3} s/layer (paper: 13 s/layer on LLaMA-7B)", r.mean_s / 7.0);
        }
    }

    // per-method baseline: every registered compressor through the
    // unified trait path, last module at module budget 0.46, 32 rows
    println!("\n# registered compressors via the Compressor trait (module budget 0.46)");
    let session = CompressionSession::new(&rt);
    let calib = exp.calibration(32, exp.xcfg.calib_seq, exp.xcfg.calib_source);
    let global = sched.global_budget(&exp.cfg);
    for compressor in all() {
        let label = format!("compressor {} rows=32", compressor.name());
        // streams are rewindable: build once outside the timed window
        // (collect_rows resets it), so the bench times only the method
        let mut stream = VecStream::new("bench", calib.clone());
        bench(&label, window, || {
            session
                .run(compressor.as_ref(), &params, sched, global, &mut stream)
                .expect("compress")
        });
    }
}
