//! Runtime-layer benchmarks: PJRT compile/execute overheads and the
//! factored-vs-dense Pallas kernels at the paper's preset budgets —
//! evidence for the #MACs column of Table 1 translating into wall-clock.
//!
//! Needs artifacts (`make artifacts`); skips gracefully otherwise.

use llm_rom::runtime::Runtime;
use llm_rom::tensor::Tensor;
use llm_rom::util::bench::{bench, default_window};
use llm_rom::util::Rng;

fn rand_tensor(shape: &[usize], rng: &mut Rng) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_f32(shape, (0..n).map(|_| rng.normal() as f32).collect())
}

fn main() {
    let Ok(rt) = Runtime::new(llm_rom::DEFAULT_ARTIFACTS) else {
        eprintln!("skipping runtime bench: artifacts missing (run `make artifacts`)");
        return;
    };
    let w = default_window();
    println!("# runtime bench (platform {})", rt.platform());
    let mut rng = Rng::new(0);

    // compile cost of a representative entry (cold cache measured once)
    let t0 = std::time::Instant::now();
    rt.warmup("covariance_d").unwrap();
    println!("compile covariance_d (cold): {:.3} s", t0.elapsed().as_secs_f64());

    // covariance kernel execute (hot cache)
    let spec = rt.manifest().entry("covariance_d").unwrap().clone();
    let y = rand_tensor(&spec.args[0].shape, &mut rng);
    bench("exec covariance_d (pallas gram 4096x128)", w, || {
        rt.execute("covariance_d", &[&y]).unwrap()
    });
    let spec_ff = rt.manifest().entry("covariance_ff").unwrap().clone();
    let yff = rand_tensor(&spec_ff.args[0].shape, &mut rng);
    bench("exec covariance_ff (pallas gram 4096x344)", w, || {
        rt.execute("covariance_ff", &[&yff]).unwrap()
    });

    // factored vs dense attention-shaped linear at the three budgets
    for key in ["b60", "b46", "b33"] {
        let lr = format!("lowrank_attn_{key}");
        let spec = rt.manifest().entry(&lr).unwrap().clone();
        let x = rand_tensor(&spec.args[0].shape, &mut rng);
        let w2 = rand_tensor(&spec.args[1].shape, &mut rng);
        let w1 = rand_tensor(&spec.args[2].shape, &mut rng);
        bench(&format!("exec {lr} (fused pallas)"), w, || {
            rt.execute(&lr, &[&x, &w2, &w1]).unwrap()
        });
        let dn = format!("dense_attn_{key}");
        let spec = rt.manifest().entry(&dn).unwrap().clone();
        let xd = rand_tensor(&spec.args[0].shape, &mut rng);
        let wd = rand_tensor(&spec.args[1].shape, &mut rng);
        bench(&format!("exec {dn} (xla dense)"), w, || {
            rt.execute(&dn, &[&xd, &wd]).unwrap()
        });
    }

    // block forward: the per-module streaming cost of the ROM pass
    let spec = rt.manifest().entry("block_fwd").unwrap().clone();
    let args: Vec<Tensor> = spec.args.iter().map(|a| rand_tensor(&a.shape, &mut rng)).collect();
    let refs: Vec<&Tensor> = args.iter().collect();
    bench("exec block_fwd (32x128 batch)", w, || rt.execute("block_fwd", &refs).unwrap());

    let spec = rt.manifest().entry("block_capture").unwrap().clone();
    let args: Vec<Tensor> = spec.args.iter().map(|a| rand_tensor(&a.shape, &mut rng)).collect();
    let refs: Vec<&Tensor> = args.iter().collect();
    bench("exec block_capture (32x128 batch)", w, || {
        rt.execute("block_capture", &refs).unwrap()
    });
}
