//! Decode-path throughput bench — the generation-side companion of the
//! serve-layer bench in `rom_layer.rs`, fully offline (synthetic artifact,
//! no PJRT):
//!
//! - KV-cached continuous-batching decode, dense vs factored execution
//!   (the `r(d1+d2)` win on the incremental path), and
//! - the cache-less full-recompute baseline, measuring what the KV cache
//!   itself buys in wall clock on top of the MAC accounting.

use llm_rom::decode::{run_recompute, synth_gen_requests, DecodeConfig, DecodeScheduler};
use llm_rom::model::ModelConfig;
use llm_rom::serve::{demo_artifact, ExecMode, ServeModel};
use llm_rom::util::bench::{bench, default_window};

fn main() {
    let window = default_window();
    let cfg = ModelConfig::mini();
    let cm = demo_artifact(&cfg, 0.5, 0xBE).expect("demo artifact");
    let reqs = synth_gen_requests(&cfg, 4, 16, 7);
    let config =
        DecodeConfig { slots: 2, capacity: 48, max_new: 24, seed: 7, ..DecodeConfig::default() };
    let generated: usize = {
        // one dry run to know the workload size (EOS may end streams early)
        let model = ServeModel::from_artifact(&cm, ExecMode::Factored).expect("model");
        let (_, stats) = DecodeScheduler::new(&model, config).run(reqs.clone()).expect("decode");
        stats.generated_tokens()
    };
    println!("# decode bench: {} requests, {generated} generated tokens per run", reqs.len());

    let mut means: Vec<(String, f64)> = Vec::new();
    for mode in [ExecMode::Dense, ExecMode::Factored] {
        let model = ServeModel::from_artifact(&cm, mode).expect("model");
        let scheduler = DecodeScheduler::new(&model, config);
        let r = bench(&format!("kv-decode {} (2 slots)", mode.name()), window, || {
            scheduler.run(reqs.clone()).expect("decode")
        });
        means.push((format!("kv-{}", mode.name()), r.mean_s));
    }
    let dense = ServeModel::from_artifact(&cm, ExecMode::Dense).expect("model");
    let r = bench("recompute dense (no cache)", window, || {
        run_recompute(&dense, &reqs, &config).expect("recompute")
    });
    means.push(("recompute-dense".to_string(), r.mean_s));

    for (label, mean_s) in &means {
        println!("    -> {label}: {:.0} tok/s", generated as f64 / mean_s);
    }
    let kv_dense = means[0].1;
    let kv_fact = means[1].1;
    let recompute = means[2].1;
    println!(
        "    -> KV cache speedup {:.2}x (dense), factorization speedup {:.2}x on top",
        recompute / kv_dense,
        kv_dense / kv_fact
    );
}
